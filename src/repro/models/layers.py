"""Shared neural layers: RMSNorm, RoPE, GQA attention (full / blockwise-flash
/ decode), SwiGLU MLP, sort-based MoE dispatch.

Everything is functional: params are plain dict pytrees, init_* builds them,
apply functions are pure. Logical-axis sharding constraints come from
repro.runtime.sharding and are no-ops without a mesh.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.runtime.sharding import shard
from repro import compat


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ RMSNorm
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- RoPE
def rope_frequencies(d_head: int, theta: float = 1e4):
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 1e4):
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # (half,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def init_attention(key, d_model, n_heads, n_kv_heads, d_head, qkv_bias, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, n_heads * d_head), dtype),
        "wk": _dense_init(ks[1], (d_model, n_kv_heads * d_head), dtype),
        "wv": _dense_init(ks[2], (d_model, n_kv_heads * d_head), dtype),
        "wo": _dense_init(ks[3], (n_heads * d_head, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, rope_theta):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, n_heads, d_head)
    k = k.reshape(b, s, n_kv_heads, d_head)
    v = v.reshape(b, s, n_kv_heads, d_head)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa_full(q, k, v, causal: bool):
    """Reference attention; fine for short sequences / smoke tests."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits /= math.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_blockwise(q, k, v, causal: bool, q_block: int, kv_block: int):
    """Memory-efficient (flash-style) attention: lax.scan over KV blocks with
    running (max, sumexp, acc) — no (S, S) intermediate ever materializes.

    Shapes: q (B, Sq, H, dh); k/v (B, Skv, KVH, dh). GQA handled by folding
    the group into the head dim per q block.
    """
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    rep = h // kvh
    scale = 1.0 / math.sqrt(dh)
    nq = sq // q_block
    nk = skv // kv_block

    qb = q.reshape(b, nq, q_block, h, dh)
    kb = k.reshape(b, nk, kv_block, kvh, dh)
    vb = v.reshape(b, nk, kv_block, kvh, dh)

    def per_qblock(qi, q_tile):
        # q_tile: (b, q_block, h, dh)
        # NOTE: python loop, not lax.scan — (a) XLA cost_analysis counts a
        # while body once regardless of trip count, which would corrupt the
        # dry-run roofline; (b) per-step jax.checkpoint keeps the backward
        # working set at one tile (flash-bwd recompute).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, kj):
            m, l, acc = carry
            k_tile = kb[:, kj]  # (b, kv_block, kvh, dh)
            v_tile = vb[:, kj]
            k_rep = jnp.repeat(k_tile, rep, axis=2)
            v_rep = jnp.repeat(v_tile, rep, axis=2)
            s_ = jnp.einsum("bqhd,bkhd->bhqk", q_tile, k_rep).astype(jnp.float32)
            s_ *= scale
            if causal:
                qpos = qi * q_block + jnp.arange(q_block)
                kpos = kj * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                s_ = jnp.where(mask[None, None], s_, -jnp.inf)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            # guard fully-masked rows (exp(-inf - -inf))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s_ - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s_), p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(m), corr, 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), v_rep
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, dh), jnp.float32)
        carry = (m0, l0, a0)
        # causal early exit: kv blocks strictly above this q block's diagonal
        # contribute nothing — skip them at trace time (halves the flops, and
        # the dry-run roofline sees the real causal cost).
        last_kj = nk if not causal else min(
            nk, ((qi + 1) * q_block + kv_block - 1) // kv_block)
        for kj in range(last_kj):
            carry, _ = kv_step(carry, kj)
        m, l, acc = carry
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (b, q_block, h, dh)

    outs = [per_qblock(qi, qb[:, qi]) for qi in range(nq)]
    return jnp.concatenate(outs, axis=1).reshape(b, sq, h, dh)


def attention(
    params,
    x,
    positions,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_theta: float,
    causal: bool = True,
    flash_threshold: int = 2048,
    q_block: int = 512,
    kv_block: int = 1024,
    return_kv: bool = False,
):
    """Self-attention over (B, S, d_model); flash path beyond the threshold."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, rope_theta)
    if s > flash_threshold and s % q_block == 0 and s % kv_block == 0:
        o = _flash_blockwise(q, k, v, causal, q_block, kv_block)
    else:
        o = _sdpa_full(q, k, v, causal)
    o = o.reshape(b, s, n_heads * d_head)
    out = shard(o @ params["wo"], "batch", "seq", "embed")
    if return_kv:
        return out, k, v
    return out


def decode_attention(params, x, cache_k, cache_v, cache_len, *,
                     n_heads, n_kv_heads, d_head, rope_theta):
    """One-token decode against a KV cache (linear in cache length).

    x: (B, 1, d); cache_k/v: (B, S_max, KVH, dh); cache_len: scalar i32 —
    number of valid cache positions. Returns (out, new_k, new_v).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, rope_theta)
    # one-hot masked insert, NOT dynamic_update_slice: a dynamic-offset
    # update on a sequence-sharded cache makes GSPMD all-gather the whole
    # cache every layer (2 GB/layer for qwen2.5 decode_32k — the dominant
    # baseline collective term, see EXPERIMENTS.md Perf iteration B). The
    # where() respects the sharding: each seq shard touches only itself.
    s_max = cache_k.shape[1]
    slot = (jnp.arange(s_max, dtype=jnp.int32) == cache_len)[None, :, None, None]
    new_k = jnp.where(slot, k.astype(cache_k.dtype), cache_k)
    new_v = jnp.where(slot, v.astype(cache_v.dtype), cache_v)
    mesh = compat.get_abstract_mesh()
    s_max = cache_k.shape[1]
    tp = mesh.axis_sizes[mesh.axis_names.index("model")] if (
        mesh is not None and not mesh.empty and "model" in mesh.axis_names) else 1
    if tp > 1 and s_max % tp == 0 and n_kv_heads % tp:
        # sequence-sharded cache: distributed flash-decode. A plain softmax
        # over the sharded seq axis makes GSPMD all-gather K AND V in f32
        # (2 GB/layer for qwen2.5 decode_32k); the manual island exchanges
        # only per-head (max, sum, o) statistics — O(B*H*dh) per chip.
        o = _flash_decode_sharded(
            q, new_k, new_v, cache_len, mesh=mesh,
            n_heads=n_heads, n_kv_heads=n_kv_heads, d_head=d_head)
    else:
        rep = n_heads // n_kv_heads
        k_all = jnp.repeat(new_k, rep, axis=2)
        v_all = jnp.repeat(new_v, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_all).astype(jnp.float32)
        logits /= math.sqrt(d_head)
        valid = jnp.arange(s_max)[None, None, None, :] <= cache_len
        logits = jnp.where(valid, logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v_all)
    o = o.reshape(b, 1, n_heads * d_head)
    return o @ params["wo"], new_k, new_v


def _flash_decode_sharded(q, k, v, cache_len, *, mesh, n_heads, n_kv_heads, d_head):
    """Exact distributed softmax over a sequence-sharded KV cache.

    Each `model` shard scores its local cache slice, then (max, sumexp,
    weighted-V) statistics merge with pmax/psum — the flash-attention
    identity across chips. Wire bytes per layer: O(B*H*(dh+2)) instead of
    the cache itself.
    """
    from jax.sharding import PartitionSpec as P

    names = set(mesh.axis_names)
    b = q.shape[0]
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    b_entry = data_axes if (data_axes and b % dp == 0) else None
    rep = n_heads // n_kv_heads
    scale = 1.0 / math.sqrt(d_head)

    def body(q_loc, k_loc, v_loc, clen):
        s_loc = k_loc.shape[1]
        my = lax.axis_index("model")
        k_all = jnp.repeat(k_loc, rep, axis=2)
        v_all = jnp.repeat(v_loc, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q_loc, k_all).astype(jnp.float32)
        logits *= scale
        gpos = my * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
        valid = (gpos <= clen)[None, None, None, :]
        logits = jnp.where(valid, logits, -jnp.inf)
        m_loc = logits.max(axis=-1)  # (b, h, 1)
        m = lax.pmax(m_loc, "model")
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(jnp.isfinite(logits), jnp.exp(logits - m_safe[..., None]), 0.0)
        denom = lax.psum(p.sum(axis=-1), "model")  # (b, h, 1)
        o_part = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q_loc.dtype), v_all)
        o = lax.psum(o_part.astype(jnp.float32), "model")
        denom = jnp.maximum(denom, 1e-30)
        return (o / denom.transpose(0, 2, 1)[..., None]).astype(q_loc.dtype)

    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(b_entry, None, None, None),
                  P(b_entry, "model", None, None),
                  P(b_entry, "model", None, None), P()),
        out_specs=P(b_entry, None, None, None),
        check_vma=False,
    )(q, k, v, cache_len)


# ------------------------------------------------------------- SwiGLU MLP
def init_mlp(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": _dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": _dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = shard(h, "batch", "seq", "ffn")
    return shard(h @ params["w_down"], "batch", "seq", "embed")


# ------------------------------------------------------------------- MoE
def init_moe(key, d_model, n_experts, d_expert, dtype):
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w_gate": _dense_init(ks[1], (n_experts, d_model, d_expert), dtype),
        "w_up": _dense_init(ks[2], (n_experts, d_model, d_expert), dtype),
        "w_down": _dense_init(ks[3], (n_experts, d_expert, d_model), dtype),
    }


def moe(params, x, *, n_experts: int, top_k: int, capacity_factor: float = 1.25):
    """Sort-based token-choice top-k MoE.

    Two execution paths with identical semantics:

    * meshless (smoke tests): global sort-based dispatch below.
    * mesh with a `model` axis: a fully-manual shard_map island — every chip
      dispatches ONLY its local tokens to ONLY its local experts (experts
      shard over `model`, tokens over data axes; activations are replicated
      over `model` by the TP layout) and the expert outputs combine with one
      psum over `model`. No global argsort, no cross-chip token gather: the
      1T-config dispatch buffer is (E/16, C_local, d) per chip instead of a
      GSPMD-replicated (T*topk, d) (which cost 1.7 TB/chip in the first
      dry-run — see EXPERIMENTS.md section Perf).

    Returns (y, aux) with aux = load-balance loss (Switch-style).
    """
    mesh = compat.get_abstract_mesh()
    if mesh is not None and not mesh.empty and "model" in mesh.axis_names:
        return _moe_manual(params, x, n_experts=n_experts, top_k=top_k,
                           capacity_factor=capacity_factor, mesh=mesh)
    return _moe_dense(params, x, n_experts=n_experts, top_k=top_k,
                      capacity_factor=capacity_factor)


def _moe_dense(params, x, *, n_experts: int, top_k: int, capacity_factor: float):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ params["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(math.ceil(t * top_k / n_experts * capacity_factor))
    capacity = max(capacity, 1)

    flat_e = gate_idx.reshape(-1)  # (T*k,)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]
    # position within expert: arange - start offset of this expert's run
    counts = jnp.bincount(sorted_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * top_k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, t * 0 + n_experts * capacity)

    buf = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[sorted_tok] * keep[:, None].astype(x.dtype))
    ebuf = buf[:-1].reshape(n_experts, capacity, d)
    ebuf = shard(ebuf, "expert", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", ebuf, params["w_up"])
    h = shard(h, "expert", None, None)
    eout = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    eout = shard(eout, "expert", None, None).reshape(n_experts * capacity, d)

    # return trip: gather each kept assignment's expert output
    contrib = jnp.where(keep[:, None], eout[jnp.minimum(slot, n_experts * capacity - 1)], 0)
    weights = gate_vals.reshape(-1)[order]
    y = jnp.zeros((t, d), jnp.float32).at[sorted_tok].add(
        contrib.astype(jnp.float32) * weights[:, None]
    )
    y = y.reshape(b, s, d).astype(x.dtype)

    # Switch load-balance aux: E * sum_e f_e * p_e
    dispatch_frac = jnp.bincount(flat_e, length=n_experts) / (t * top_k)
    router_frac = probs.mean(axis=0)
    aux = n_experts * jnp.sum(dispatch_frac * router_frac)
    return shard(y, "batch", "seq", "embed"), aux


def _moe_manual(params, x, *, n_experts: int, top_k: int,
                capacity_factor: float, mesh):
    """Expert-parallel MoE as a manual shard_map island (see moe() docstring).

    Layout contract: activations (B, S, d) shard B over the data axes and
    replicate over `model`; expert weights (E, d, de) shard E over `model`.
    Every chip routes its local tokens to its local E/tp experts and the
    per-chip expert outputs combine with one psum over `model` — collective
    volume identical to the TP MLP combine, dispatch entirely chip-local.
    """
    from jax.sharding import PartitionSpec as P

    names = set(mesh.axis_names)
    tp = mesh.shape["model"]
    if n_experts % tp:
        raise ValueError(f"{n_experts} experts not divisible by model={tp}")
    e_loc = n_experts // tp
    b, s, d = x.shape
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    b_entry = data_axes if (data_axes and b % dp == 0) else None
    b_loc = b // dp if b_entry else b
    t_loc = b_loc * s
    capacity = max(4, int(math.ceil(t_loc * top_k / n_experts * capacity_factor)))

    def body(xl, router, wg, wu, wd):
        bl = xl.shape[0]
        t = bl * s
        xf = xl.reshape(t, d)
        logits = xf.astype(jnp.float32) @ router  # full E per chip
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        my = lax.axis_index("model")
        lo = my * e_loc
        flat_e = gate_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
        flat_w = gate_vals.reshape(-1)
        is_local = (flat_e >= lo) & (flat_e < lo + e_loc)
        loc_e = jnp.where(is_local, flat_e - lo, e_loc)  # e_loc = trash bucket
        order = jnp.argsort(loc_e, stable=True)
        s_e, s_t, s_w = loc_e[order], flat_t[order], flat_w[order]
        counts = jnp.bincount(s_e, length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * top_k, dtype=jnp.int32) - starts[s_e]
        keep = (s_e < e_loc) & (pos < capacity)
        slot = jnp.where(keep, s_e * capacity + pos, e_loc * capacity)

        buf = jnp.zeros((e_loc * capacity + 1, d), xl.dtype)
        buf = buf.at[slot].set(xf[s_t] * keep[:, None].astype(xl.dtype))
        ebuf = buf[:-1].reshape(e_loc, capacity, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", ebuf, wu)
        eout = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * capacity, d)
        contrib = jnp.where(
            keep[:, None], eout[jnp.minimum(slot, e_loc * capacity - 1)], 0)
        y = jnp.zeros((t, d), jnp.float32).at[s_t].add(
            contrib.astype(jnp.float32) * s_w[:, None])
        # combine in bf16: halves the dominant MoE wire+HBM traffic (2x61
        # layers of (T_loc, d) per step); the f32 local accumulate above
        # keeps the per-chip sum exact before the cast (Perf iteration C).
        y = lax.psum(y.astype(jnp.bfloat16), "model")

        dispatch_frac = jnp.bincount(flat_e, length=n_experts) / (t * top_k)
        aux = n_experts * jnp.sum(dispatch_frac * probs.mean(axis=0))
        aux = lax.pmean(aux, ("model",) + tuple(data_axes))
        return y.reshape(bl, s, d).astype(x.dtype), aux

    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(b_entry, None, None), P(), P("model"), P("model"), P("model")),
        out_specs=(P(b_entry, None, None), P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
