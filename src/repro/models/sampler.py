"""CSR neighbor sampler for sampled-training GNN shapes (minibatch_lg).

Host-side (numpy) fanout sampling a la GraphSAGE: given a CSR adjacency,
sample `fanouts[l]` neighbors per frontier node per hop, uniformly without
replacement, and emit a padded fixed-shape subgraph the jitted train step
consumes (fixed shapes => one executable).

For n_nodes=232_965 / fanout 15-10 / batch 1024 the padded budget is
    hop0 edges: 1024*15 = 15_360
    hop1 edges: (1024 + 15_360)*10 = 163_840
    nodes <= 1024 + 15_360 + 163_840 = 180_224
Real samples are smaller (duplicates); padding is masked.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SampledSubgraph:
    node_ids: np.ndarray  # (N_pad,) global ids, -1 pad
    node_feat: np.ndarray  # (N_pad, d)
    senders: np.ndarray  # (E_pad,) local indices
    receivers: np.ndarray  # (E_pad,)
    edge_mask: np.ndarray  # (E_pad,) bool
    node_mask: np.ndarray  # (N_pad,) bool
    seed_mask: np.ndarray  # (N_pad,) bool — loss only on seeds


class CSRGraph:
    """Compressed adjacency built once on the host."""

    def __init__(self, n_nodes: int, senders: np.ndarray, receivers: np.ndarray):
        order = np.argsort(receivers, kind="stable")
        self.src_sorted = senders[order].astype(np.int64)
        counts = np.bincount(receivers, minlength=n_nodes)
        self.offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.n_nodes = n_nodes

    def neighbors(self, v: int) -> np.ndarray:
        return self.src_sorted[self.offsets[v] : self.offsets[v + 1]]


def edge_budget(batch_nodes: int, fanouts: tuple[int, ...]) -> tuple[int, int]:
    """(node_pad, edge_pad) for fixed-shape compilation."""
    frontier, nodes, edges = batch_nodes, batch_nodes, 0
    for f in fanouts:
        e = frontier * f
        edges += e
        frontier = e
        nodes += e
    return nodes, edges


def sample_subgraph(
    graph: CSRGraph,
    features: np.ndarray,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> SampledSubgraph:
    n_pad, e_pad = edge_budget(len(seeds), fanouts)
    local = {int(v): i for i, v in enumerate(seeds)}
    node_ids = list(map(int, seeds))
    snd, rcv = [], []
    frontier = list(map(int, seeds))
    for f in fanouts:
        nxt = []
        for v in frontier:
            nbrs = graph.neighbors(v)
            if len(nbrs) == 0:
                continue
            take = nbrs if len(nbrs) <= f else rng.choice(nbrs, size=f, replace=False)
            for u in map(int, take):
                if u not in local:
                    local[u] = len(node_ids)
                    node_ids.append(u)
                    nxt.append(u)
                snd.append(local[u])
                rcv.append(local[v])
        frontier = nxt
    n, e = len(node_ids), len(snd)
    assert n <= n_pad and e <= e_pad, (n, n_pad, e, e_pad)
    ids = np.full(n_pad, -1, np.int64)
    ids[:n] = node_ids
    feat = np.zeros((n_pad, features.shape[1]), features.dtype)
    feat[:n] = features[ids[:n]]
    senders = np.zeros(e_pad, np.int32)
    receivers = np.zeros(e_pad, np.int32)
    senders[:e] = snd
    receivers[:e] = rcv
    edge_mask = np.zeros(e_pad, bool)
    edge_mask[:e] = True
    node_mask = np.zeros(n_pad, bool)
    node_mask[:n] = True
    seed_mask = np.zeros(n_pad, bool)
    seed_mask[: len(seeds)] = True
    return SampledSubgraph(ids, feat, senders, receivers, edge_mask, node_mask, seed_mask)


def minibatch_stream(
    graph: CSRGraph,
    features: np.ndarray,
    batch_nodes: int,
    fanouts: tuple[int, ...],
    seed: int = 0,
):
    """Endless generator of sampled subgraphs (feeds the double-buffered
    device prefetcher in repro.data.pipeline)."""
    rng = np.random.default_rng(seed)
    while True:
        seeds = rng.choice(graph.n_nodes, size=batch_nodes, replace=False)
        yield sample_subgraph(graph, features, seeds, fanouts, rng)
