"""Transformer LM (dense + MoE, GQA + RoPE) with scan-over-layers.

Covers all five assigned LM architectures through one config-driven
implementation. Layer parameters are stacked on a leading L axis and the
forward pass is a lax.scan (+ optional remat) — compile time and HLO size
stay flat in depth (61-layer kimi compiles the same program as 2-layer
smoke configs).

Entry points:
    init(key, cfg)                  -> params pytree
    apply(params, cfg, tokens)      -> logits  (training forward, causal)
    loss_fn(params, cfg, batch)     -> (loss, aux)
    init_cache(cfg, batch, max_len) -> decode cache pytree
    decode_step(params, cfg, cache, token) -> (logits, cache)  serve_step
    param_specs(cfg)                -> PartitionSpec pytree (FSDP x TP)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.runtime.sharding import resolve, shard


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int  # dense FFN hidden; for MoE archs this is the per-expert dim
    vocab: int
    n_experts: int = 0  # 0 => dense FFN
    expert_top_k: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    capacity_factor: float = 1.25
    remat: bool = True
    scan_unroll: bool = False  # fully unroll layer scan (dry-run cost probes)
    dtype: Any = jnp.bfloat16
    flash_threshold: int = 2048
    q_block: int = 512
    kv_block: int = 1024

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to 128 (Megatron-style): keeps the vocab dim
        shardable over a 16-way model axis and MXU-lane aligned. Pad logits
        are masked to -inf; pad ids are never emitted (minicpm's odd
        122753 -> 122880)."""
        return ((self.vocab + 127) // 128) * 128

    def params_count(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.vocab_padded * d * 2 + self.n_layers * per_layer + d

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.params_count()
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        ffn = self.expert_top_k * 3 * d * self.d_ff + d * self.n_experts
        per_layer = attn + ffn + 2 * d
        return self.vocab_padded * d * 2 + self.n_layers * per_layer + d


# ------------------------------------------------------------------- init
def init(key: jax.Array, cfg: LMConfig):
    k_embed, k_layers, k_head = jax.random.split(key, 3)

    def init_layer(k):
        ka, km = jax.random.split(k)
        p = {
            "ln1": L.init_rmsnorm(cfg.d_model, jnp.float32),
            "ln2": L.init_rmsnorm(cfg.d_model, jnp.float32),
            "attn": L.init_attention(
                ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                cfg.qkv_bias, cfg.dtype,
            ),
        }
        if cfg.is_moe:
            p["moe"] = L.init_moe(km, cfg.d_model, cfg.n_experts, cfg.d_ff, cfg.dtype)
        else:
            p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.dtype)
        return p

    layer_params = jax.vmap(init_layer)(jax.random.split(k_layers, cfg.n_layers))
    vp = cfg.vocab_padded
    return {
        "embed": (jax.random.normal(k_embed, (vp, cfg.d_model), jnp.float32)
                  * 0.02).astype(cfg.dtype),
        "layers": layer_params,
        "final_norm": L.init_rmsnorm(cfg.d_model, jnp.float32),
        "head": (jax.random.normal(k_head, (cfg.d_model, vp), jnp.float32)
                 / math.sqrt(cfg.d_model)).astype(cfg.dtype),
    }


def param_specs(cfg: LMConfig, training: bool = True):
    """PartitionSpec pytree: TP over `model`; FSDP over (pod, data) when
    training. For SERVING (training=False) params replicate over the data
    axes instead: decode would otherwise all-gather every FSDP shard on
    every step — the dominant collective term of the baseline decode cells
    (EXPERIMENTS.md section Perf, iteration B).

    Stacked layer params have a leading L axis (never sharded). Matrices
    shard the TP-parallel dim over `model` and the other dim over the fsdp
    axes — ZeRO-3-style fully-sharded parameters.
    """
    fsdp = resolve(("fsdp",))[0] if training else None
    tp = resolve(("heads",))[0]

    def mat(d_in_ax, d_out_ax, stacked=True):
        spec = (d_in_ax, d_out_ax)
        return P(*((None,) + spec if stacked else spec))

    attn = {
        "wq": mat(fsdp, tp), "wk": mat(fsdp, tp), "wv": mat(fsdp, tp),
        "wo": mat(tp, fsdp),
    }
    if cfg.qkv_bias:
        attn.update({"bq": P(None, tp), "bk": P(None, tp), "bv": P(None, tp)})
    layer = {
        "ln1": {"scale": P(None, None)},
        "ln2": {"scale": P(None, None)},
        "attn": attn,
    }
    if cfg.is_moe:
        layer["moe"] = {
            "router": P(None, None, None),
            "w_gate": P(None, tp, fsdp, None),
            "w_up": P(None, tp, fsdp, None),
            "w_down": P(None, tp, None, fsdp),
        }
    else:
        layer["mlp"] = {
            "w_gate": mat(fsdp, tp), "w_up": mat(fsdp, tp), "w_down": mat(tp, fsdp),
        }
    return {
        "embed": P(tp, fsdp),
        "layers": layer,
        "final_norm": {"scale": P(None)},
        "head": P(fsdp, tp),
    }


# ---------------------------------------------------------------- forward
def _layer_forward(cfg: LMConfig, lp, x, positions):
    h = L.attention(
        L_params := lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), positions,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
        rope_theta=cfg.rope_theta, causal=True,
        flash_threshold=cfg.flash_threshold, q_block=cfg.q_block,
        kv_block=cfg.kv_block,
    )
    x = x + h
    y = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        m, aux = L.moe(
            lp["moe"], y, n_experts=cfg.n_experts, top_k=cfg.expert_top_k,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        m, aux = L.mlp(lp["mlp"], y), jnp.float32(0)
    return x + m, aux


def apply(params, cfg: LMConfig, tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, V) f32, moe aux loss)."""
    b, s = tokens.shape
    x = params["embed"][tokens]  # gather; sharded table => all-gather of rows
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_forward(cfg, lp, x, positions)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = lax.scan(body_fn, (x, jnp.float32(0)), params["layers"],
                           unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    logits = _mask_pad_vocab(logits, cfg)
    return shard(logits, "batch", "seq", "vocab"), aux


def _mask_pad_vocab(logits: jax.Array, cfg: LMConfig) -> jax.Array:
    if cfg.vocab_padded == cfg.vocab:
        return logits
    v_ids = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(v_ids < cfg.vocab, logits, -jnp.inf)


def loss_fn(params, cfg: LMConfig, batch) -> tuple[jax.Array, dict]:
    """Causal LM loss; batch = {"tokens": (B, S+1)} or {"tokens","labels"}."""
    if "labels" in batch:
        tokens, labels = batch["tokens"], batch["labels"]
    else:
        tokens, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
    logits, aux = apply(params, cfg, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    total = nll + 0.01 * aux
    return total, {"nll": nll, "moe_aux": aux}


def prefill(params, cfg: LMConfig, tokens: jax.Array, max_len: int | None = None):
    """Serving prefill: (B, S) tokens -> (last-token logits (B, V), cache).

    Never materializes (B, S, V) logits (640 GB for qwen2.5 at 32k x 32) —
    only the last position projects through the head. The per-layer K/V come
    back as scan ys and become the decode cache.
    """
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, lp):
        h, k, v = L.attention(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            rope_theta=cfg.rope_theta, causal=True,
            flash_threshold=cfg.flash_threshold, q_block=cfg.q_block,
            kv_block=cfg.kv_block, return_kv=True,
        )
        x = x + h
        y = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            m, _ = L.moe(lp["moe"], y, n_experts=cfg.n_experts,
                         top_k=cfg.expert_top_k, capacity_factor=cfg.capacity_factor)
        else:
            m = L.mlp(lp["mlp"], y)
        return x + m, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = lax.scan(body_fn, x, params["layers"],
                           unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _mask_pad_vocab((x[:, -1, :] @ params["head"]).astype(jnp.float32), cfg)
    if max_len is not None and max_len > s:
        pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "len": jnp.int32(s)}
    return logits, cache


# ----------------------------------------------------------------- decode
def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: LMConfig):
    """KV cache sharding: batch over data, kv-heads over model; for batch=1
    long-context the sequence dim shards over model instead (flash-merge
    handled by XLA's SPMD partitioner on the masked softmax)."""
    batch_ax = resolve(("batch",))[0]
    tp = resolve(("kv_heads",))[0]
    return {
        "k": P(None, batch_ax, None, tp, None),
        "v": P(None, batch_ax, None, tp, None),
        "len": P(),
    }


def decode_step(params, cfg: LMConfig, cache, tokens: jax.Array):
    """One decode step: tokens (B, 1) -> logits (B, V); cache advances by 1.

    Scan over layers with the per-layer cache slice as carry-free xs/ys.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    cache_len = cache["len"]

    def body(x, xs):
        lp, ck, cv = xs
        h, nk, nv = L.decode_attention(
            lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), ck, cv, cache_len,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            rope_theta=cfg.rope_theta,
        )
        x = x + h
        y = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.is_moe:
            m, _ = L.moe(
                lp["moe"], y, n_experts=cfg.n_experts, top_k=cfg.expert_top_k,
                capacity_factor=max(cfg.capacity_factor, 8.0),  # tiny T decode
            )
        else:
            m = L.mlp(lp["mlp"], y)
        return x + m, (nk, nv)

    x, (nk, nv) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                           unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _mask_pad_vocab((x[:, 0, :] @ params["head"]).astype(jnp.float32), cfg)
    new_cache = {"k": nk, "v": nv, "len": cache_len + 1}
    return logits, new_cache
