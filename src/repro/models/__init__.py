"""Model substrates: transformer LMs (dense/MoE), MeshGraphNet, recsys."""
