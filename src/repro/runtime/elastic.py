"""Elastic scaling: re-mesh a training job onto a different device count.

When a pod loses hosts (or gains them back), the job restarts on a new mesh.
Checkpoints store FULL arrays (repro.checkpoint), so elasticity reduces to:

    1. build the new mesh from the surviving devices (largest (data, model)
       grid that divides the workload),
    2. re-derive PartitionSpecs against it (repro.runtime.sharding sanitizes
       non-divisible axes automatically),
    3. load the checkpoint with the new shardings,
    4. re-jit the step (executable cache keyed by mesh).

tests/test_fault.py round-trips 4 -> 2 -> 4 devices with bitwise-identical
params.
"""
from __future__ import annotations

import jax

from repro import compat
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def best_mesh_shape(n_devices: int, prefer_model: int = 16) -> tuple[int, int]:
    """Largest (data, model) grid for the available devices: model axis as
    close to `prefer_model` as divisibility allows, rest data."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return n_devices // model, model


def remesh(devices=None, prefer_model: int = 16) -> jax.sharding.Mesh:
    devices = list(devices if devices is not None else jax.devices())
    data, model = best_mesh_shape(len(devices), prefer_model)
    return compat.make_mesh(
        (data, model), ("data", "model"), devices=devices[: data * model])


def reshard_tree(tree, specs, mesh: jax.sharding.Mesh):
    """Re-place a (host or device) pytree onto `mesh` under `specs`,
    sanitizing non-divisible axes (see runtime.sharding.sanitize_tree)."""
    from repro.runtime.sharding import sanitize_spec

    sizes = {a: mesh.shape[a] for a in mesh.axis_names}

    def place(x, spec):
        arr = np.asarray(jax.device_get(x))
        sp = sanitize_spec(arr.shape, spec, sizes)
        return jax.device_put(arr, NamedSharding(mesh, sp))

    return jax.tree.map(place, tree, specs)
