"""Fault-tolerant training supervision: heartbeats, straggler detection,
crash recovery, failure injection for tests.

At 1000+ nodes the failure model is: a host dies mid-step (checkpoint /
restart), or a host slows down (straggler — thermal throttle, flaky HBM,
network). The supervisor wraps the step loop:

* every step is timed; an EWMA + deviation tracker flags steps slower than
  `straggler_factor` x the running mean (on real multi-host deployments the
  per-host step times come from the coordination service; here the detector
  consumes whatever timing stream it is given, so tests inject synthetic
  host timings);
* on a flagged straggler the policy hook fires (log / re-shard / evict);
* on an exception the loop restores the latest checkpoint and replays —
  `max_restarts` bounds the retry budget;
* `FailureInjector` deterministically raises at chosen steps to exercise
  the recovery path in CI.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time outlier detection (per host or global)."""

    alpha: float = 0.2
    straggler_factor: float = 2.0
    warmup: int = 3
    mean: float = 0.0
    count: int = 0
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float, host: int = 0) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            # compile/warmup steps are excluded from the baseline
            self.mean = seconds if self.mean == 0 else self.mean
            return False
        is_straggler = seconds > self.straggler_factor * self.mean
        if is_straggler:
            self.flagged.append({"step": step, "host": host, "seconds": seconds,
                                 "mean": self.mean})
        else:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * seconds
        return is_straggler


class FailureInjector:
    """Raises RuntimeError at the given steps (once each) — CI chaos monkey."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class SupervisorReport:
    steps_done: int
    restarts: int
    stragglers: list
    losses: list


def supervised_train(
    step_fn: Callable,  # (state, batch) -> (state, metrics)
    init_state: Any,
    batches: Callable[[int], Any],  # step -> batch
    n_steps: int,
    manager: CheckpointManager,
    injector: FailureInjector | None = None,
    detector: StragglerDetector | None = None,
    max_restarts: int = 3,
    on_straggler: Callable[[dict], None] | None = None,
) -> tuple[Any, SupervisorReport]:
    """Run n_steps with checkpoint/restart fault tolerance.

    The loop is deterministic given `batches`: after a restart the state is
    restored from the newest checkpoint and the step counter rewinds with
    it, so recovered training is step-for-step identical to an unfailed run
    (asserted by tests/test_fault.py).
    """
    detector = detector or StragglerDetector()
    restarts = 0
    losses: list[float] = []

    state, step = manager.restore_latest(init_state)
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if injector is not None:
                injector.maybe_fail(step)
            state, metrics = step_fn(state, batches(step))
            dt = time.perf_counter() - t0
            if detector.observe(step, dt) and on_straggler:
                on_straggler(detector.flagged[-1])
            losses.append(float(metrics.get("loss", 0.0)))
            step += 1
            manager.save(step, state, {"losses_len": len(losses)})
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            state, step = manager.restore_latest(init_state)
    manager.save(n_steps, state, force=True)
    manager.finalize()
    return state, SupervisorReport(
        steps_done=step, restarts=restarts,
        stragglers=list(detector.flagged), losses=losses,
    )
