"""Sharding rules — logical axis names resolved against the active mesh.

Model code annotates tensors with LOGICAL axes ("batch", "seq", "embed",
"heads", "ffn", "expert", "vocab", "rows", "edges", ...). The rules map
logical axes to mesh axes; anything unmapped is replicated. On a meshless
CPU test run every constraint is a no-op, so the same model code serves
smoke tests, training, and the multi-pod dry-run.

Default rules target the production mesh (pod, data, model):
    batch  -> (pod, data)     activations/data parallel
    embed  -> model  (FSDP param shard: weights gather per-layer)
    heads/ffn/expert/vocab/rows -> model   (tensor/expert/table parallel)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "seq": None,
    "embed": None,  # replicated activations along d_model
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "expert": "model",
    "vocab": "model",
    "rows": "model",  # embedding-table / dataset rows
    "fsdp": ("pod", "data"),  # parameter sharding axis for ZeRO-3
    "edges": ("pod", "data", "model"),  # GNN edge partitions
    "nodes": None,
}

_local = threading.local()


def current_rules() -> Mapping[str, object]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: Mapping[str, object]):
    prev = getattr(_local, "rules", DEFAULT_RULES)
    _local.rules = dict(rules)
    try:
        yield
    finally:
        _local.rules = prev


def _mesh_axis_names() -> tuple[str, ...]:
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


def resolve(logical: Sequence[str | None]) -> P:
    """Translate logical axes to a PartitionSpec under the current rules,
    dropping mesh axes that do not exist on the active mesh."""
    names = _mesh_axis_names()
    rules = current_rules()
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
            continue
        tgt = rules.get(ax)
        if tgt is None:
            out.append(None)
        elif isinstance(tgt, tuple):
            present = tuple(t for t in tgt if t in names)
            out.append(present if len(present) > 1 else (present[0] if present else None))
        else:
            out.append(tgt if tgt in names else None)
    return P(*out)


def _mesh_axis_sizes() -> Mapping[str, int]:
    mesh = compat.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _axis_product(entry, sizes: Mapping[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        p = 1
        for e in entry:
            p *= sizes.get(e, 1)
        return p
    return sizes.get(entry, 1)


def sanitize_spec(shape: Sequence[int], spec: P, sizes: Mapping[str, int] | None = None) -> P:
    """Drop spec axes whose mesh-size does not divide the dim evenly.

    jit in_shardings rejects uneven shards (XLA pads only through
    with_sharding_constraint), so e.g. minicpm's odd vocab=122753 falls back
    to replicated on that dim. Starcoder2's 36 heads similarly drop the
    16-way head axis at the activation level.
    """
    sizes = _mesh_axis_sizes() if sizes is None else sizes
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _axis_product(entry, sizes) == 0 else None)
    return P(*out)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh;
    drops axes that do not divide the dim (uneven shards)."""
    if not _mesh_axis_names():
        return x
    sp = sanitize_spec(x.shape, resolve(logical))
    return jax.lax.with_sharding_constraint(x, sp)


def spec(*logical: str | None) -> P:
    """PartitionSpec for in_shardings/out_shardings construction."""
    return resolve(logical)


def sanitize_tree(shapes_tree, specs_tree, mesh: jax.sharding.Mesh):
    """Per-leaf sanitize_spec over a (ShapeDtypeStruct tree, spec tree) pair.

    specs_tree leaves must be PartitionSpec; shapes_tree leads the map so
    spec subtrees may be shared/broadcast (e.g. one layer-spec dict against
    stacked layer params).
    """
    sizes = {a: mesh.shape[a] for a in mesh.axis_names}
    return jax.tree.map(
        lambda s, sp: sanitize_spec(s.shape, sp, sizes), shapes_tree, specs_tree
    )
