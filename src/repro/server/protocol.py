"""Wire layer: minimal HTTP/1.1 + WebSocket over asyncio streams, and the
JSON codec between request bodies and the frozen :class:`SearchRequest`.

Stdlib-only by design — the runtime dependency set stays jax + numpy
(requirements-dev.txt), so the front end ships no web framework. The
implementation covers exactly what the serving plane needs:

* HTTP/1.1 request parsing with persistent connections (``Connection:
  keep-alive`` default), bounded header and body sizes, and typed 4xx
  errors (:class:`BadRequest` -> 400, :class:`PayloadTooLarge` -> 413)
  raised **at the boundary**, before any engine work.
* JSON responses with non-finite floats sanitized to ``null`` (a shed
  result's +inf scores must not emit invalid JSON).
* The RFC 6455 server handshake + frame codec (text/close/ping/pong, 16-
  and 64-bit extended lengths, client masking) for the stats stream.
* :func:`parse_search_request` — every wire field of the search body
  (queries/k/metric/tier/mode_hint/deadline_ms/filter_mask/allow_partial/
  max_retries/rid/tenant) validated with a named error message; unknown
  fields are rejected rather than silently dropped. Construction errors
  from ``SearchRequest.__post_init__`` surface as 400s too, so the wire
  contract and the API contract are the same contract.
"""
from __future__ import annotations

import asyncio
import base64
import dataclasses
import hashlib
import json
import math
import os
import struct
from typing import Any, Mapping
from urllib.parse import parse_qsl, unquote, urlsplit

import numpy as np

from repro.api.types import SearchRequest, SearchResult

__all__ = [
    "ProtocolError", "BadRequest", "PayloadTooLarge", "ConnectionClosed",
    "HttpRequest", "read_http_request", "http_response", "jsonable",
    "ws_accept_key", "ws_frame", "ws_read_frame",
    "OP_TEXT", "OP_BINARY", "OP_CLOSE", "OP_PING", "OP_PONG",
    "parse_search_request", "encode_result",
    "MAX_BODY_BYTES_DEFAULT",
]

#: default request-body ceiling (per request, enforced before the read)
MAX_BODY_BYTES_DEFAULT = 8 << 20

_REASONS = {
    101: "Switching Protocols",
    200: "OK",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    408: "Request Timeout",
    413: "Payload Too Large",
    429: "Too Many Requests",
    500: "Internal Server Error",
    503: "Service Unavailable",
}


class ConnectionClosed(Exception):
    """The peer closed the connection (clean EOF between requests)."""


class ProtocolError(Exception):
    """A wire-level error with an HTTP status; the server answers it and
    (when ``close`` is True) drops the connection, never crashes."""

    status = 400
    #: some errors leave unread bytes in the stream (an oversized body is
    #: never read), so the connection cannot be reused
    close = False

    def __init__(self, message: str):
        super().__init__(message)
        self.message = message


class BadRequest(ProtocolError):
    status = 400


class PayloadTooLarge(ProtocolError):
    status = 413
    close = True


@dataclasses.dataclass
class HttpRequest:
    """One parsed HTTP/1.1 request."""

    method: str
    target: str                  # raw request target (path + query)
    path: str                    # decoded path only
    query: dict[str, str]
    headers: dict[str, str]      # keys lowercased
    body: bytes

    def json(self) -> Any:
        """Parse the body as JSON; malformed bodies are a 400, always."""
        if not self.body:
            raise BadRequest("empty body where a JSON object was expected")
        try:
            return json.loads(self.body)
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise BadRequest(f"malformed JSON body: {e}") from None

    @property
    def keep_alive(self) -> bool:
        return self.headers.get("connection", "").lower() != "close"


async def read_http_request(
    reader: asyncio.StreamReader,
    max_body_bytes: int = MAX_BODY_BYTES_DEFAULT,
) -> HttpRequest:
    """Read one request off the stream; raises :class:`ConnectionClosed`
    on clean EOF, typed :class:`ProtocolError` on anything malformed."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            raise ConnectionClosed from None
        raise BadRequest("truncated request head") from None
    except asyncio.LimitOverrunError:
        err = BadRequest("request head exceeds the stream limit")
        err.close = True
        raise err from None
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split(" ")
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise BadRequest(f"malformed request line: {lines[0]!r}")
    method, target = parts[0].upper(), parts[1]
    headers: dict[str, str] = {}
    for line in lines[1:]:
        if not line:
            continue
        name, sep, value = line.partition(":")
        if not sep:
            raise BadRequest(f"malformed header line: {line!r}")
        headers[name.strip().lower()] = value.strip()
    split = urlsplit(target)
    query = dict(parse_qsl(split.query, keep_blank_values=True))
    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError:
            raise BadRequest(
                f"malformed Content-Length: {headers['content-length']!r}"
            ) from None
        if n < 0:
            raise BadRequest(f"negative Content-Length: {n}")
        if n > max_body_bytes:
            # refuse BEFORE reading: the bytes stay unread in the stream,
            # so the error closes the connection after answering 413
            raise PayloadTooLarge(
                f"body of {n} bytes exceeds the {max_body_bytes}-byte limit"
            )
        try:
            body = await reader.readexactly(n)
        except asyncio.IncompleteReadError:
            raise ConnectionClosed from None
    elif headers.get("transfer-encoding"):
        err = BadRequest("chunked request bodies are not supported")
        err.close = True
        raise err
    return HttpRequest(method=method, target=target,
                       path=unquote(split.path), query=query,
                       headers=headers, body=body)


def jsonable(obj: Any) -> Any:
    """Recursively convert stats payloads to strict JSON: numpy scalars to
    Python numbers, sets/tuples to sorted lists/lists, non-finite floats
    to None (strict JSON has no Infinity/NaN)."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, (set, frozenset)):
        return sorted(jsonable(v) for v in obj)
    if isinstance(obj, np.ndarray):
        return [jsonable(v) for v in obj.tolist()]
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        f = float(obj)
        return f if math.isfinite(f) else None
    return obj


def http_response(
    status: int,
    payload: Any = None,
    headers: Mapping[str, str] | None = None,
    close: bool = False,
) -> bytes:
    """One full HTTP/1.1 response; dict payloads are JSON-encoded."""
    if payload is None:
        body = b""
        ctype = None
    elif isinstance(payload, (bytes, bytearray)):
        body = bytes(payload)
        ctype = "application/octet-stream"
    else:
        body = json.dumps(jsonable(payload), allow_nan=False).encode()
        ctype = "application/json"
    lines = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}"]
    if ctype is not None:
        lines.append(f"Content-Type: {ctype}")
    lines.append(f"Content-Length: {len(body)}")
    lines.append(f"Connection: {'close' if close else 'keep-alive'}")
    for name, value in (headers or {}).items():
        lines.append(f"{name}: {value}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + body


# ------------------------------------------------------------------ websocket
_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"
OP_TEXT, OP_BINARY, OP_CLOSE, OP_PING, OP_PONG = 0x1, 0x2, 0x8, 0x9, 0xA


def ws_accept_key(client_key: str) -> str:
    """RFC 6455 handshake digest for ``Sec-WebSocket-Accept``."""
    digest = hashlib.sha1((client_key + _WS_GUID).encode()).digest()
    return base64.b64encode(digest).decode()


def ws_frame(payload: bytes | str, opcode: int = OP_TEXT,
             mask: bool = False) -> bytes:
    """Encode one final frame. Servers send unmasked; a client (the load
    generator, tests) passes ``mask=True`` as RFC 6455 requires."""
    data = payload.encode() if isinstance(payload, str) else bytes(payload)
    head = bytearray([0x80 | (opcode & 0x0F)])
    mask_bit = 0x80 if mask else 0
    n = len(data)
    if n < 126:
        head.append(mask_bit | n)
    elif n < 1 << 16:
        head.append(mask_bit | 126)
        head += struct.pack(">H", n)
    else:
        head.append(mask_bit | 127)
        head += struct.pack(">Q", n)
    if mask:
        key = os.urandom(4)
        head += key
        data = bytes(b ^ key[i % 4] for i, b in enumerate(data))
    return bytes(head) + data


async def ws_read_frame(reader: asyncio.StreamReader) -> tuple[int, bytes]:
    """Read one frame; returns (opcode, unmasked payload)."""
    try:
        b0, b1 = await reader.readexactly(2)
        n = b1 & 0x7F
        if n == 126:
            (n,) = struct.unpack(">H", await reader.readexactly(2))
        elif n == 127:
            (n,) = struct.unpack(">Q", await reader.readexactly(8))
        key = await reader.readexactly(4) if b1 & 0x80 else None
        data = await reader.readexactly(n) if n else b""
    except (asyncio.IncompleteReadError, ConnectionResetError):
        raise ConnectionClosed from None
    if key is not None:
        data = bytes(b ^ key[i % 4] for i, b in enumerate(data))
    return b0 & 0x0F, data


# ------------------------------------------------------------- search codec
#: every field the search body accepts; anything else is a named 400
_SEARCH_FIELDS = frozenset({
    "queries", "k", "metric", "tier", "mode_hint", "deadline_ms",
    "filter_mask", "allow_partial", "max_retries", "rid", "tenant",
})
_METRICS = ("l2", "ip", "cos")


def _as_int(payload: Mapping, field: str) -> int | None:
    v = payload.get(field)
    if v is None:
        return None
    if isinstance(v, bool) or not isinstance(v, int):
        raise BadRequest(f"'{field}' must be an integer, got {v!r}")
    return v


def _as_bool(payload: Mapping, field: str) -> bool:
    v = payload.get(field, False)
    if not isinstance(v, bool):
        raise BadRequest(f"'{field}' must be a boolean, got {v!r}")
    return v


def parse_search_request(
    payload: Any,
    arrival_s: float = 0.0,
    n_ids: int | None = None,
) -> tuple[SearchRequest, str]:
    """Body dict -> (frozen :class:`SearchRequest`, tenant id).

    Every violation raises :class:`BadRequest` naming the offending field —
    the 4xx happens at the boundary, never inside the dispatch path.
    ``n_ids`` (the collection's global id-space size) validates the
    ``filter_mask`` length up front when known.
    """
    if not isinstance(payload, Mapping):
        raise BadRequest(
            f"request body must be a JSON object, got {type(payload).__name__}"
        )
    unknown = sorted(set(payload) - _SEARCH_FIELDS)
    if unknown:
        raise BadRequest(
            f"unknown field(s) {unknown}; accepted: {sorted(_SEARCH_FIELDS)}"
        )
    if "queries" not in payload:
        raise BadRequest("missing required field 'queries'")
    try:
        q = np.asarray(payload["queries"], dtype=np.float32)
    except (TypeError, ValueError) as e:
        raise BadRequest(f"'queries' is not a numeric array: {e}") from None
    if q.ndim not in (1, 2) or q.size == 0:
        raise BadRequest(
            f"'queries' must be a (d,) vector or (m, d) matrix, got shape "
            f"{q.shape}"
        )
    if not np.all(np.isfinite(q)):
        raise BadRequest("'queries' contains non-finite values")

    tenant = payload.get("tenant", "default")
    if not isinstance(tenant, str) or not tenant:
        raise BadRequest(f"'tenant' must be a non-empty string, got {tenant!r}")

    metric = payload.get("metric")
    if metric is not None and metric not in _METRICS:
        raise BadRequest(f"'metric' must be one of {_METRICS}, got {metric!r}")

    deadline_ms = payload.get("deadline_ms")
    if deadline_ms is not None:
        if isinstance(deadline_ms, bool) or not isinstance(
                deadline_ms, (int, float)):
            raise BadRequest(f"'deadline_ms' must be a number, got "
                             f"{deadline_ms!r}")
        deadline_ms = float(deadline_ms)
        if not math.isfinite(deadline_ms) or deadline_ms <= 0:
            raise BadRequest(f"'deadline_ms' must be a positive finite "
                             f"number, got {deadline_ms}")

    mask = payload.get("filter_mask")
    if mask is not None:
        try:
            mask = np.asarray(mask)
        except (TypeError, ValueError) as e:
            raise BadRequest(f"'filter_mask' is not an array: {e}") from None
        if mask.ndim != 1 or mask.dtype.kind not in "biu":
            raise BadRequest(
                "'filter_mask' must be a flat list of booleans/0-1 over the "
                f"collection's id space, got dtype {mask.dtype} shape "
                f"{mask.shape}"
            )
        mask = mask.astype(bool)
        if n_ids is not None and mask.shape[0] != n_ids:
            raise BadRequest(
                f"'filter_mask' has {mask.shape[0]} entries but the "
                f"collection's id space holds {n_ids}"
            )

    tier = payload.get("tier", "auto")
    mode_hint = payload.get("mode_hint", "auto")
    try:
        req = SearchRequest(
            queries=q,
            k=_as_int(payload, "k"),
            metric=metric,
            tier=tier,
            mode_hint=mode_hint,
            deadline_ms=deadline_ms,
            filter_mask=mask,
            allow_partial=_as_bool(payload, "allow_partial"),
            max_retries=_as_int(payload, "max_retries"),
            rid=_as_int(payload, "rid"),
            arrival_s=arrival_s,
        )
    except (TypeError, ValueError) as e:
        # SearchRequest.__post_init__ validation (k >= 1, tier/mode_hint
        # vocabularies, max_retries >= 0, ...) IS the wire contract
        raise BadRequest(str(e)) from None
    if req.tier == "int8" and req.mode_hint == "fdsq":
        raise BadRequest(
            "tier='int8' is a throughput (FQ-SD) tier and cannot serve "
            "mode_hint='fdsq'"
        )
    return req, tenant


def encode_result(result: SearchResult) -> dict:
    """One served :class:`SearchResult` -> response body dict.

    Shed results (`stats["mode"] == "shed"`) keep their documented envelope
    — empty top-k, ``shed: true`` — rather than pretending to be answers.
    """
    stats = dict(result.stats)
    shed = bool(stats.get("shed", False))
    body = {
        "rid": result.rid,
        "mode": stats.get("mode"),
        "tier": result.tier,
        "shed": shed,
        "partial": bool(stats.get("partial", False)),
        "stats": {
            "latency_ms": stats.get("latency_ms"),
            "batched": stats.get("batched"),
            "deadline_ms": stats.get("deadline_ms"),
            "health": stats.get("health", {}),
        },
    }
    if shed:
        body["scores"] = []
        body["indices"] = []
        body["certified"] = False
    else:
        body["scores"] = np.asarray(result.scores)
        body["indices"] = np.asarray(result.indices)
        body["certified"] = result.certified
    return jsonable(body)
