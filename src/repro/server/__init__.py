"""Async network front end: multi-tenant serving over :class:`repro.api.Router`.

Stdlib-only (asyncio streams + a minimal HTTP/1.1 + WebSocket codec) — the
runtime dependency set stays jax + numpy. The stack, top to bottom:

- :mod:`repro.server.app` — :class:`KnnServer`: endpoints, routing, the
  degradation ladder (4xx parse -> 429 admission -> 503 queue timeout ->
  shed envelope -> circuit breaker).
- :mod:`repro.server.admission` — per-tenant sliding-window rate limits,
  inflight quotas, deadline-aware admission.
- :mod:`repro.server.batching` — continuous (iteration-level) batching
  feeding :class:`repro.serving.AdaptiveScheduler` on a worker thread.
- :mod:`repro.server.protocol` — wire codec: HTTP parsing, JSON -> frozen
  :class:`repro.api.types.SearchRequest` validation, WebSocket frames.
- :mod:`repro.server.loadgen` — closed-/open-loop load generator and the
  acceptance soak (``python -m repro.server.loadgen --selfhost``).
"""
from repro.server.admission import AdmissionController, Verdict
from repro.server.app import KnnServer
from repro.server.batching import ContinuousBatcher, ServerClosed
from repro.server.protocol import (
    BadRequest,
    PayloadTooLarge,
    ProtocolError,
    encode_result,
    parse_search_request,
)

__all__ = [
    "AdmissionController",
    "BadRequest",
    "ContinuousBatcher",
    "KnnServer",
    "PayloadTooLarge",
    "ProtocolError",
    "ServerClosed",
    "Verdict",
    "encode_result",
    "parse_search_request",
]
