"""Admission control: per-tenant sliding-window rate limits + quotas and
deadline-aware queue admission for the live front end.

The degradation ladder (docs/serving.md) starts here: a request is first
checked against its tenant's sliding-window rate limit and inflight quota,
then against the server-wide inflight bound, then against its own deadline
— if the continuous batcher's backlog already predicts a start time past
the request's deadline, the server answers **429 + Retry-After now**
instead of queueing work it provably cannot serve in time (queueing it
would only be shed later, after burning a queue slot on it). Everything
admitted is accounted as inflight until :meth:`AdmissionController.release`
— the slot is released in the handler's ``finally``, so disconnects and
timeouts can never leak it.

This layer is synchronous, allocation-light, and owns no locks: it runs on
the event loop only. The scheduler's shed/circuit-breaker machinery (PR 8)
sits *below* it — admission rejects work before it enters the queue,
shedding answers work that expired inside it, the breaker degrades work
that keeps failing after dispatch.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque

__all__ = ["AdmissionController", "Verdict"]


@dataclasses.dataclass(frozen=True)
class Verdict:
    """One admission decision. ``admitted=False`` carries the HTTP status
    (always 429 here), a machine-readable reason bucket, and the
    Retry-After hint in seconds."""

    admitted: bool
    status: int = 200
    reason: str = ""
    retry_after_s: float = 0.0


class _Tenant:
    __slots__ = ("arrivals", "inflight", "admitted", "rejected")

    def __init__(self):
        self.arrivals: deque[float] = deque()
        self.inflight = 0
        self.admitted = 0
        self.rejected = 0


class AdmissionController:
    """Sliding-window rate limits, quotas, and deadline-aware admission.

    max_inflight         server-wide bound on admitted-but-unanswered
                         requests (the bounded queue; None = unbounded).
    tenant_qps           per-tenant sustained request rate over a sliding
                         ``window_s`` window (None = unlimited). The
                         window admits ``ceil(tenant_qps * window_s)``
                         arrivals, so short bursts above the rate pass as
                         long as the window average holds.
    tenant_max_inflight  per-tenant inflight quota (None = unlimited).
    window_s             sliding-window width in seconds.
    clock                injectable monotonic clock (tests).
    """

    def __init__(
        self,
        max_inflight: int | None = 256,
        tenant_qps: float | None = None,
        tenant_max_inflight: int | None = None,
        window_s: float = 1.0,
        clock=time.monotonic,
    ):
        if max_inflight is not None and max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if tenant_qps is not None and tenant_qps <= 0:
            raise ValueError(f"tenant_qps must be > 0, got {tenant_qps}")
        if tenant_max_inflight is not None and tenant_max_inflight < 1:
            raise ValueError(
                f"tenant_max_inflight must be >= 1, got {tenant_max_inflight}"
            )
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.max_inflight = max_inflight
        self.tenant_qps = tenant_qps
        self.tenant_max_inflight = tenant_max_inflight
        self.window_s = float(window_s)
        self._clock = clock
        self._tenants: dict[str, _Tenant] = {}
        self.inflight = 0
        self.admitted = 0
        self.rejected = {"rate_limit": 0, "quota": 0, "capacity": 0,
                         "deadline": 0}

    # ------------------------------------------------------------- decisions
    def _tenant(self, tenant: str) -> _Tenant:
        t = self._tenants.get(tenant)
        if t is None:
            t = self._tenants[tenant] = _Tenant()
        return t

    def try_admit(
        self,
        tenant: str,
        deadline_ms: float | None = None,
        predicted_wait_s: float = 0.0,
    ) -> Verdict:
        """Admit or reject one request, in ladder order: tenant rate limit,
        tenant quota, server capacity, deadline feasibility.

        ``predicted_wait_s`` is the batcher's estimate of time-to-first-
        dispatch given the current backlog; a request whose deadline cannot
        survive that wait is rejected *now* with Retry-After instead of
        being queued only to be shed at dispatch.
        """
        t = self._tenant(tenant)
        now = self._clock()
        horizon = now - self.window_s
        while t.arrivals and t.arrivals[0] <= horizon:
            t.arrivals.popleft()

        def reject(reason: str, retry_after_s: float) -> Verdict:
            t.rejected += 1
            self.rejected[reason] += 1
            return Verdict(False, 429, reason,
                           max(retry_after_s, 1e-3))

        if self.tenant_qps is not None:
            allowance = max(1, math.ceil(self.tenant_qps * self.window_s))
            if len(t.arrivals) >= allowance:
                # retry once the oldest arrival slides out of the window
                return reject("rate_limit",
                              t.arrivals[0] + self.window_s - now)
        if (self.tenant_max_inflight is not None
                and t.inflight >= self.tenant_max_inflight):
            return reject("quota", predicted_wait_s or self.window_s)
        if self.max_inflight is not None and self.inflight >= self.max_inflight:
            return reject("capacity", predicted_wait_s or self.window_s)
        if (deadline_ms is not None
                and predicted_wait_s * 1e3 > deadline_ms):
            # cannot meet the deadline given the backlog: reject instead of
            # queueing a guaranteed shed
            return reject("deadline", predicted_wait_s)

        t.arrivals.append(now)
        t.inflight += 1
        t.admitted += 1
        self.inflight += 1
        self.admitted += 1
        return Verdict(True)

    def release(self, tenant: str) -> None:
        """Return one admitted request's slot (handler ``finally``)."""
        t = self._tenants.get(tenant)
        if t is not None and t.inflight > 0:
            t.inflight -= 1
        if self.inflight > 0:
            self.inflight -= 1

    # ----------------------------------------------------------------- stats
    def stats(self) -> dict:
        return {
            "inflight": self.inflight,
            "admitted": self.admitted,
            "rejected": dict(self.rejected),
            "limits": {
                "max_inflight": self.max_inflight,
                "tenant_qps": self.tenant_qps,
                "tenant_max_inflight": self.tenant_max_inflight,
                "window_s": self.window_s,
            },
            "tenants": {
                name: {"inflight": t.inflight, "admitted": t.admitted,
                       "rejected": t.rejected}
                for name, t in sorted(self._tenants.items())
            },
        }
