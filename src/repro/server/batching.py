"""Continuous batching: drain the live queue into option-compatible
batches and feed :class:`AdaptiveScheduler` without blocking the event
loop.

One :class:`ContinuousBatcher` fronts one collection's scheduler. The loop
is iteration-level batching, the same discipline LLM serving engines use:
while a dispatch runs on the worker executor, new arrivals keep landing in
the queue; the moment the dispatch returns, the next batch is formed from
*everything* compatible that accumulated — so batch size adapts to load
with no batching-window timer to tune, latency stays one-dispatch-bounded
under light load, and throughput approaches the scheduler's ``max_batch``
under heavy load.

Batches group by :meth:`AdaptiveScheduler.batch_signature` — the same
option-compatibility rule the dispatch path enforces (k/metric/tier/mode
pins/filter mask/resilience knobs), so a mixed-tenant queue never forces a
plan-incompatible dispatch. Scheduler dispatch runs in a **worker thread
executor** (`loop.run_in_executor`): the compiled-executable cache and the
engines are single-threaded by design, so the server shares ONE worker
thread across all collections — the event loop stays free to admit,
reject, and stream stats while the accelerator crunches.

Results resolve per-request futures as their dispatch completes; a future
whose waiter vanished (client disconnect, queue timeout) is skipped at
batch-formation time, so dead requests never occupy dispatch slots.
"""
from __future__ import annotations

import asyncio
import functools
from collections import deque

from repro.api.types import SearchRequest

__all__ = ["ContinuousBatcher", "ServerClosed"]


class ServerClosed(RuntimeError):
    """The batcher is draining/stopped; no further requests are accepted."""


class ContinuousBatcher:
    """The live queue + dispatch loop for one collection.

    scheduler   the collection's AdaptiveScheduler (dispatch_batch entry).
    executor    shared worker ThreadPoolExecutor (single worker: engine
                dispatch is deliberately serialized across collections).
    """

    def __init__(self, scheduler, executor):
        self.scheduler = scheduler
        self._executor = executor
        self._queue: deque[tuple[SearchRequest, asyncio.Future]] = deque()
        self._wakeup = asyncio.Event()
        self._task: asyncio.Task | None = None
        self._closed = False
        #: EWMA of one dispatch's wall time — the backlog-to-wait estimate
        #: admission control reads (seeded pessimistically low so the first
        #: dispatches are never rejected on a cold estimate)
        self._ewma_dispatch_s: float | None = None
        self.dispatched_batches = 0

    # ---------------------------------------------------------------- intake
    def submit(self, request: SearchRequest) -> asyncio.Future:
        """Enqueue one admitted request; resolves to its SearchResult."""
        if self._closed:
            raise ServerClosed("server is shutting down")
        fut = asyncio.get_running_loop().create_future()
        self._queue.append((request, fut))
        self.scheduler.note_queue_depth(len(self._queue))
        self._wakeup.set()
        return fut

    def depth(self) -> int:
        return len(self._queue)

    def predicted_wait_s(self) -> float:
        """Admission's feasibility estimate: dispatches-ahead x EWMA
        dispatch time. With an empty queue one dispatch (the request's own)
        is still ahead of the answer."""
        est = self._ewma_dispatch_s
        if est is None:
            return 0.0  # cold start: admit — the EWMA warms on dispatch 1
        take = max(1, self.scheduler.max_batch)
        batches_ahead = 1 + len(self._queue) // take
        return batches_ahead * est

    # -------------------------------------------------------------- dispatch
    def _take_batch(self) -> list[tuple[SearchRequest, asyncio.Future]]:
        """Pop the next option-compatible batch; drop abandoned entries."""
        sig = None
        batch: list[tuple[SearchRequest, asyncio.Future]] = []
        take = max(1, self.scheduler.max_batch)
        while self._queue and len(batch) < take:
            req, fut = self._queue[0]
            if fut.done():  # cancelled by timeout/disconnect: skip it
                self._queue.popleft()
                continue
            key = self.scheduler.batch_signature(req)
            if sig is None:
                sig = key
            elif key != sig:
                break  # next compatibility group waits for its own dispatch
            batch.append((req, fut))
            self._queue.popleft()
        self.scheduler.note_queue_depth(len(self._queue))
        return batch

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            await self._wakeup.wait()
            self._wakeup.clear()
            if self._closed:
                break
            while self._queue:
                batch = self._take_batch()
                if not batch:
                    continue
                reqs = [r for r, _ in batch]
                clock = loop.time()
                t0 = clock
                try:
                    results = await loop.run_in_executor(
                        self._executor,
                        functools.partial(
                            self.scheduler.dispatch_batch, reqs, clock),
                    )
                except Exception as e:  # engine/storage error: per-request
                    for _, fut in batch:
                        if not fut.done():
                            fut.set_exception(e)
                else:
                    dt = loop.time() - t0
                    ema = self._ewma_dispatch_s
                    self._ewma_dispatch_s = (
                        dt if ema is None else 0.7 * ema + 0.3 * dt)
                    self.dispatched_batches += 1
                    for (_, fut), res in zip(batch, results):
                        if not fut.done():
                            fut.set_result(res)
        # drain: everything still queued is answered with ServerClosed
        while self._queue:
            _, fut = self._queue.popleft()
            if not fut.done():
                fut.set_exception(ServerClosed("server is shutting down"))
        self.scheduler.note_queue_depth(0)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._run())

    async def stop(self) -> None:
        self._closed = True
        self._wakeup.set()
        if self._task is not None:
            await self._task
            self._task = None

    def stats(self) -> dict:
        return {
            "queue_depth": len(self._queue),
            "dispatched_batches": self.dispatched_batches,
            "ewma_dispatch_ms": (None if self._ewma_dispatch_s is None
                                 else self._ewma_dispatch_s * 1e3),
        }
