"""Closed-/open-loop asyncio load generator for the HTTP front end.

Closed loop: N concurrent connections, each issuing its next request the
moment the previous answer lands — the classic saturation probe (achieved
qps = what the server actually sustains at concurrency N). Open loop:
requests fire on an arrival schedule regardless of completions — the
arrival shapes reuse :func:`repro.serving.bursty_requests` (dense bursts +
sparse trickle), so the same workload the discrete-event replay exercises
drives the real socket path.

Both modes measure achieved qps, p50/p99 latency, shed/reject/error rates,
and deadline attainment; :func:`stats_stream_probe` rides a WebSocket
alongside to assert the dashboard channel stays live under load. The CLI
self-host mode boots a 2-collection router server in-process and runs the
acceptance soak (below saturation: p99 within deadline; past saturation:
graceful 429s, never a hang or crash) — CI's load-generator smoke job and
the ISSUE 9 acceptance criterion both call it.

Stdlib + numpy only, like everything under ``repro.server``.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
import time

import numpy as np

from repro.server import protocol

__all__ = ["LoadReport", "Connection", "closed_loop", "open_loop",
           "stats_stream_probe"]


@dataclasses.dataclass
class LoadReport:
    """One load run's measurements."""

    mode: str
    duration_s: float
    sent: int = 0
    ok: int = 0
    shed: int = 0
    rejected: int = 0        # 429 (admission: rate limit / quota / deadline)
    timeouts: int = 0        # 503 queue timeouts
    errors: int = 0          # anything else non-200
    disconnects: int = 0
    partial: int = 0
    degraded: int = 0        # answers whose health.degraded was non-empty
    latencies_ms: list = dataclasses.field(default_factory=list)
    deadline_ms: float | None = None
    deadline_met: int = 0

    def observe(self, status: int, body: dict, latency_ms: float) -> None:
        self.sent += 1
        if status == 200 and not body.get("shed"):
            self.ok += 1
            self.latencies_ms.append(latency_ms)
            if body.get("partial"):
                self.partial += 1
            if body.get("stats", {}).get("health", {}).get("degraded"):
                self.degraded += 1
            if self.deadline_ms is not None and latency_ms <= self.deadline_ms:
                self.deadline_met += 1
        elif status == 200:
            self.shed += 1
        elif status == 429:
            self.rejected += 1
        elif status == 503:
            self.timeouts += 1
        else:
            self.errors += 1

    # ------------------------------------------------------------ summaries
    def percentile_ms(self, q: float) -> float:
        if not self.latencies_ms:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_ms), q))

    @property
    def achieved_qps(self) -> float:
        return self.ok / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def reject_rate(self) -> float:
        return self.rejected / self.sent if self.sent else 0.0

    @property
    def shed_rate(self) -> float:
        return self.shed / self.sent if self.sent else 0.0

    def summary(self) -> dict:
        out = {
            "mode": self.mode,
            "duration_s": round(self.duration_s, 3),
            "sent": self.sent, "ok": self.ok, "shed": self.shed,
            "rejected": self.rejected, "timeouts": self.timeouts,
            "errors": self.errors, "disconnects": self.disconnects,
            "partial": self.partial, "degraded": self.degraded,
            "achieved_qps": round(self.achieved_qps, 2),
            "p50_ms": round(self.percentile_ms(50), 3),
            "p99_ms": round(self.percentile_ms(99), 3),
            "shed_rate": round(self.shed_rate, 4),
            "reject_rate": round(self.reject_rate, 4),
        }
        if self.deadline_ms is not None:
            out["deadline_ms"] = self.deadline_ms
            out["deadline_attainment"] = round(
                self.deadline_met / self.ok, 4) if self.ok else 0.0
        return out


class Connection:
    """One persistent HTTP/1.1 client connection (reconnects on failure)."""

    def __init__(self, host: str, port: int, report: LoadReport):
        self.host, self.port = host, port
        self.report = report
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None

    async def _ensure_open(self) -> None:
        if self._writer is None or self._writer.is_closing():
            self._reader, self._writer = await asyncio.open_connection(
                self.host, self.port)

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except Exception:
                pass
            self._writer = None

    async def request(self, method: str, path: str, payload=None,
                      headers: dict | None = None) -> tuple[int, dict]:
        """Issue one request; returns (status, body-dict)."""
        body = b"" if payload is None else json.dumps(payload).encode()
        head = [f"{method} {path} HTTP/1.1",
                f"Host: {self.host}:{self.port}",
                f"Content-Length: {len(body)}",
                "Content-Type: application/json"]
        for name, value in (headers or {}).items():
            head.append(f"{name}: {value}")
        raw = ("\r\n".join(head) + "\r\n\r\n").encode() + body
        try:
            await self._ensure_open()
            self._writer.write(raw)
            await self._writer.drain()
            status, resp = await _read_response(self._reader)
        except (ConnectionError, asyncio.IncompleteReadError, OSError):
            self.report.disconnects += 1
            await self.close()
            raise
        return status, resp


async def _read_response(reader: asyncio.StreamReader) -> tuple[int, dict]:
    head = await reader.readuntil(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ", 2)[1])
    n = 0
    for line in lines[1:]:
        if line.lower().startswith("content-length:"):
            n = int(line.split(":", 1)[1])
    body = await reader.readexactly(n) if n else b""
    payload = json.loads(body) if body else {}
    return status, payload


def _default_payload_fn(d: int, k: int, deadline_ms: float | None, seed=0):
    rng = np.random.default_rng(seed)

    def payload(i: int) -> dict:
        body = {"queries": rng.standard_normal(d).astype(np.float32).tolist(),
                "k": k, "rid": i}
        if deadline_ms is not None:
            body["deadline_ms"] = deadline_ms
        return body

    return payload


# ------------------------------------------------------------- closed loop
async def closed_loop(
    host: str,
    port: int,
    collection: str,
    *,
    connections: int = 64,
    duration_s: float = 10.0,
    payload_fn=None,
    d: int = 32,
    k: int = 10,
    deadline_ms: float | None = None,
    tenant_fn=None,
    honor_retry_after: bool = True,
) -> LoadReport:
    """N workers, each one connection, each firing back-to-back requests
    for ``duration_s``. Rejected workers back off by the server's
    Retry-After (well-behaved clients) unless ``honor_retry_after=False``
    (adversarial saturation)."""
    report = LoadReport(mode="closed", duration_s=duration_s,
                        deadline_ms=deadline_ms)
    payload_fn = payload_fn or _default_payload_fn(d, k, deadline_ms)
    path = f"/v1/collections/{collection}/search"
    t_end = time.perf_counter() + duration_s

    async def worker(wid: int) -> None:
        conn = Connection(host, port, report)
        i = wid * 1_000_000
        try:
            while time.perf_counter() < t_end:
                headers = ({"X-Tenant": tenant_fn(wid)}
                           if tenant_fn is not None else None)
                t0 = time.perf_counter()
                try:
                    status, body = await conn.request(
                        "POST", path, payload_fn(i), headers=headers)
                except (ConnectionError, asyncio.IncompleteReadError,
                        OSError):
                    continue  # reconnect next iteration, already counted
                lat_ms = (time.perf_counter() - t0) * 1e3
                report.observe(status, body, lat_ms)
                i += 1
                if status == 429 and honor_retry_after:
                    retry_ms = float(body.get("retry_after_ms", 50.0))
                    await asyncio.sleep(min(retry_ms / 1e3, 1.0))
        finally:
            await conn.close()

    t0 = time.perf_counter()
    await asyncio.gather(*(worker(w) for w in range(connections)))
    report.duration_s = time.perf_counter() - t0
    return report


# --------------------------------------------------------------- open loop
async def open_loop(
    host: str,
    port: int,
    collection: str,
    *,
    n_requests: int = 512,
    burst_size: int = 64,
    trickle: int = 8,
    burst_gap_s: float = 0.25,
    trickle_gap_s: float = 0.02,
    d: int = 32,
    k: int = 10,
    deadline_ms: float | None = None,
    max_connections: int = 256,
) -> LoadReport:
    """Fire requests on the bursty arrival schedule regardless of
    completions (arrival shapes from ``serving.bursty_requests``); each
    in-flight request rides its own pooled connection."""
    from repro.serving import bursty_requests

    rng = np.random.default_rng(1)
    vectors = rng.standard_normal((n_requests, d)).astype(np.float32)
    schedule = [
        (r.arrival_s, r.rid, np.asarray(r.queries))
        for r in bursty_requests(vectors, burst_size, trickle,
                                 burst_gap_s, trickle_gap_s)
    ]
    report = LoadReport(mode="open", duration_s=0.0, deadline_ms=deadline_ms)
    path = f"/v1/collections/{collection}/search"
    sem = asyncio.Semaphore(max_connections)
    t0 = time.perf_counter()

    async def fire(rid: int, vec: np.ndarray) -> None:
        async with sem:
            conn = Connection(host, port, report)
            body = {"queries": vec.tolist(), "k": k, "rid": rid}
            if deadline_ms is not None:
                body["deadline_ms"] = deadline_ms
            t_req = time.perf_counter()
            try:
                status, resp = await conn.request("POST", path, body)
            except (ConnectionError, asyncio.IncompleteReadError, OSError):
                return
            finally:
                await conn.close()
            report.observe(status, resp, (time.perf_counter() - t_req) * 1e3)

    tasks = []
    for arrival_s, rid, vec in schedule:
        delay = t0 + arrival_s - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        tasks.append(asyncio.create_task(fire(rid, vec)))
    await asyncio.gather(*tasks)
    report.duration_s = time.perf_counter() - t0
    return report


# ---------------------------------------------------------- stats stream
async def stats_stream_probe(host: str, port: int, duration_s: float,
                             interval_ms: float = 100.0) -> list[dict]:
    """Ride the WebSocket stats stream for ``duration_s``; returns every
    received stats frame (callers assert liveness + content)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((
        f"GET /v1/stats/stream?interval_ms={interval_ms:g} HTTP/1.1\r\n"
        f"Host: {host}:{port}\r\n"
        "Upgrade: websocket\r\nConnection: Upgrade\r\n"
        "Sec-WebSocket-Key: bG9hZGdlbi1wcm9iZQ==\r\n"
        "Sec-WebSocket-Version: 13\r\n\r\n"
    ).encode())
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    if b" 101 " not in head.split(b"\r\n", 1)[0]:
        raise RuntimeError(f"WebSocket upgrade refused: {head[:80]!r}")
    frames: list[dict] = []
    t_end = time.perf_counter() + duration_s
    try:
        while time.perf_counter() < t_end:
            budget = t_end - time.perf_counter()
            try:
                opcode, payload = await asyncio.wait_for(
                    protocol.ws_read_frame(reader), timeout=max(budget, 0.01))
            except asyncio.TimeoutError:
                break
            if opcode == protocol.OP_TEXT:
                frames.append(json.loads(payload))
            elif opcode == protocol.OP_CLOSE:
                break
        writer.write(protocol.ws_frame(b"", opcode=protocol.OP_CLOSE,
                                       mask=True))
        await writer.drain()
    except (ConnectionError, protocol.ConnectionClosed):
        pass
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except Exception:
            pass
    return frames


# ------------------------------------------------------------- CLI / soak
def _build_selfhost_server(args):
    """A 2-collection Router server for the self-contained soak."""
    from repro.api import Router
    from repro.server.app import KnnServer

    rng = np.random.default_rng(0)
    router = Router()
    for name in ("passages", "images"):
        x = rng.standard_normal((args.n, args.d)).astype(np.float32)
        router.create(name, x, k=args.k, n_partitions=4)
    return KnnServer(
        router, host=args.host, port=args.port,
        max_inflight=args.max_inflight,
        tenant_qps=args.tenant_qps,
        queue_timeout_ms=args.queue_timeout_ms,
        fqsd_min_depth=8,
    )


async def _soak(args) -> int:
    """Two-phase acceptance: (1) below saturation — measured p99 within
    the request deadline, zero non-graceful errors, stats stream live
    throughout; (2) past saturation (tight per-tenant rate limit) —
    non-zero graceful 429s, still zero errors/hangs."""
    server = _build_selfhost_server(args)
    async with server:
        host, port = server.address
        print(f"selfhost: listening on {host}:{port} "
              f"collections={list(server.router.collections())}")
        probe = asyncio.create_task(stats_stream_probe(
            host, port, args.duration + args.duration / 2 + 2.0))

        # phase 1: modest closed loop on both collections, no rate limit
        # pressure — p99 must clear the deadline
        reports = await asyncio.gather(*(
            closed_loop(host, port, name,
                        connections=args.connections // 2,
                        duration_s=args.duration, d=args.d, k=args.k,
                        deadline_ms=args.deadline_ms,
                        tenant_fn=lambda w: f"tenant-{w % 8}")
            for name in ("passages", "images")))
        ok = True
        for name, rep in zip(("passages", "images"), reports):
            s = rep.summary()
            print(f"phase1 {name}: {s}")
            if rep.errors or rep.ok == 0:
                print(f"FAIL: {name} saw {rep.errors} hard errors / "
                      f"{rep.ok} answers", file=sys.stderr)
                ok = False
            if (args.deadline_ms is not None
                    and rep.percentile_ms(99) > args.deadline_ms):
                print(f"FAIL: {name} p99 {rep.percentile_ms(99):.1f}ms "
                      f"over the {args.deadline_ms}ms deadline",
                      file=sys.stderr)
                ok = False

        # phase 2: saturate one tenant past its sliding-window budget —
        # the server must reject gracefully (429 + Retry-After), not hang
        server.admission.tenant_qps = args.saturate_tenant_qps
        rep2 = await closed_loop(
            host, port, "passages",
            connections=args.connections, duration_s=args.duration / 2,
            d=args.d, k=args.k, deadline_ms=args.deadline_ms,
            tenant_fn=lambda w: "hot-tenant",
            honor_retry_after=False)
        print(f"phase2 (saturated): {rep2.summary()}")
        if rep2.rejected == 0:
            print("FAIL: saturation phase produced zero 429s",
                  file=sys.stderr)
            ok = False
        if rep2.errors:
            print(f"FAIL: saturation phase saw {rep2.errors} hard errors",
                  file=sys.stderr)
            ok = False

        frames = await probe
        print(f"stats stream: {len(frames)} frames")
        if len(frames) < 2:
            print("FAIL: stats stream went silent during the soak",
                  file=sys.stderr)
            ok = False
        else:
            last = frames[-1]["schedulers"]["passages"]
            print(f"  last frame: served={last['served']} "
                  f"queue_depth={last['queue_depth']} "
                  f"breaker_open={last['circuit_breaker']['open']}")
    return 0 if ok else 1


async def _against(args) -> int:
    """Drive an already-running server (no asserts, just the report)."""
    rep = await closed_loop(
        args.host, args.port, args.collection,
        connections=args.connections, duration_s=args.duration,
        d=args.d, k=args.k, deadline_ms=args.deadline_ms)
    print(json.dumps(rep.summary(), indent=2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="closed-/open-loop load generator for the kNN HTTP "
                    "front end")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--collection", default="passages")
    ap.add_argument("--connections", type=int, default=64)
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--deadline-ms", type=float, default=1000.0)
    ap.add_argument("--n", type=int, default=8192,
                    help="selfhost corpus rows per collection")
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-inflight", type=int, default=1024)
    ap.add_argument("--tenant-qps", type=float, default=None)
    ap.add_argument("--queue-timeout-ms", type=float, default=None)
    ap.add_argument("--saturate-tenant-qps", type=float, default=25.0,
                    help="phase-2 per-tenant rate limit (the saturation "
                         "probe must draw 429s against it)")
    ap.add_argument("--selfhost", action="store_true",
                    help="boot a 2-collection router server in-process and "
                         "run the two-phase acceptance soak against it")
    args = ap.parse_args(argv)
    if args.selfhost:
        return asyncio.run(_soak(args))
    if not args.port:
        ap.error("--port is required without --selfhost")
    return asyncio.run(_against(args))


if __name__ == "__main__":
    sys.exit(main())
