"""The asyncio network front end over :class:`repro.api.Router`.

Endpoints (HTTP/1.1, persistent connections, JSON bodies):

    POST /v1/collections/<name>/search    one SearchRequest -> one result
    POST /v1/collections/<name>/upsert    {"vectors": [[...], ...]} -> ids
    POST /v1/collections/<name>/delete    {"ids": [...]} -> count
    POST /v1/collections/<name>/compact   fold delta+tombstones; {"wait": true}
    GET  /v1/collections/<name>/compact   compaction status (generation, ...)
    GET  /healthz                         liveness + per-collection health
    GET  /stats                           schedulers + admission + router
    GET  /v1/stats/stream                 WebSocket: pushed stats frames

Request lifecycle — the degradation ladder end to end:

    parse (4xx at the boundary) -> admission (per-tenant sliding-window
    rate limit / quota / server capacity / deadline feasibility -> 429 +
    Retry-After) -> bounded queue (continuous batcher; queue-timeout ->
    503) -> scheduler dispatch (expired requests shed with the documented
    shed envelope; per-collection circuit breaker degrades repeated
    storage failures) -> response.

The admission slot is acquired before enqueue and released in the handler
``finally`` — disconnects, timeouts, and engine errors can never leak it.
Engine dispatch runs on ONE shared worker thread (the compiled-executable
cache and the engines are single-threaded by design); the event loop
itself only parses, admits, and streams, so thousands of connections ride
one accelerator dispatch stream.
"""
from __future__ import annotations

import asyncio
import contextlib
import json
import logging
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.faults import FaultError
from repro.serving.retrieval import AdaptiveScheduler
from repro.server import protocol
from repro.server.admission import AdmissionController
from repro.server.batching import ContinuousBatcher, ServerClosed

__all__ = ["KnnServer"]

log = logging.getLogger("repro.server")


class KnnServer:
    """Serve an :class:`repro.api.Router` over HTTP + WebSocket.

    Usage::

        server = KnnServer(router, host="127.0.0.1", port=8080,
                           max_inflight=512, tenant_qps=200.0)
        async with server:                 # starts listening
            await server.serve_forever()

    One :class:`AdaptiveScheduler` + :class:`ContinuousBatcher` pair per
    collection; all pairs share one admission controller and one dispatch
    worker thread. Scheduler knobs (policy, int8_min_depth, ...) apply to
    every collection's scheduler.
    """

    def __init__(
        self,
        router,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        policy: str = "adaptive",
        fdsq_max_batch: int = 4,
        fqsd_min_depth: int = 32,
        max_batch: int = 256,
        int8_min_depth: int | None = None,
        max_inflight: int | None = 512,
        tenant_qps: float | None = None,
        tenant_max_inflight: int | None = None,
        queue_timeout_ms: float | None = None,
        max_body_bytes: int = protocol.MAX_BODY_BYTES_DEFAULT,
        stats_interval_ms: float = 500.0,
    ):
        if queue_timeout_ms is not None and queue_timeout_ms <= 0:
            raise ValueError(
                f"queue_timeout_ms must be > 0, got {queue_timeout_ms}")
        if max_body_bytes < 1:
            raise ValueError(
                f"max_body_bytes must be >= 1, got {max_body_bytes}")
        if stats_interval_ms < 10:
            raise ValueError(
                f"stats_interval_ms must be >= 10, got {stats_interval_ms}")
        self.router = router
        self.host = host
        self.port = port
        self.queue_timeout_ms = queue_timeout_ms
        self.max_body_bytes = int(max_body_bytes)
        self.stats_interval_ms = float(stats_interval_ms)
        self.admission = AdmissionController(
            max_inflight=max_inflight,
            tenant_qps=tenant_qps,
            tenant_max_inflight=tenant_max_inflight,
        )
        # ONE dispatch worker: the executor layer's compiled-executable
        # cache is shared, unlocked state — all collections serialize on it
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="knn-dispatch")
        self.schedulers: dict[str, AdaptiveScheduler] = {}
        self.batchers: dict[str, ContinuousBatcher] = {}
        for name in router.collections():
            self.schedulers[name] = AdaptiveScheduler(
                router=router, collection=name, policy=policy,
                fdsq_max_batch=fdsq_max_batch,
                fqsd_min_depth=fqsd_min_depth, max_batch=max_batch,
                int8_min_depth=int8_min_depth,
            )
        self._server: asyncio.base_events.Server | None = None
        self._ws_streams = 0
        self.connections = 0

    # ------------------------------------------------------------- lifecycle
    async def start(self) -> None:
        for name, sched in self.schedulers.items():
            batcher = ContinuousBatcher(sched, self._executor)
            batcher.start()
            self.batchers[name] = batcher
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    @property
    def address(self) -> tuple[str, int]:
        return self.host, self.port

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for batcher in self.batchers.values():
            await batcher.stop()
        self._executor.shutdown(wait=True)

    async def __aenter__(self) -> "KnnServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------------ connection
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        """One connection: keep-alive request loop, typed error answers,
        never an unhandled exception out of here."""
        self.connections += 1
        try:
            while True:
                try:
                    req = await protocol.read_http_request(
                        reader, max_body_bytes=self.max_body_bytes)
                except protocol.ConnectionClosed:
                    return
                except protocol.ProtocolError as e:
                    writer.write(protocol.http_response(
                        e.status, {"error": e.message}, close=e.close))
                    await writer.drain()
                    if e.close:
                        return
                    continue
                try:
                    done = await self._route(req, reader, writer)
                except (ConnectionResetError, BrokenPipeError):
                    return  # peer vanished mid-response
                except protocol.ProtocolError as e:
                    writer.write(protocol.http_response(
                        e.status, {"error": e.message}, close=e.close))
                    await writer.drain()
                    done = e.close
                except Exception:
                    # last line of defense: answer 500, keep serving others
                    log.exception("unhandled error serving %s %s",
                                  req.method, req.path)
                    writer.write(protocol.http_response(
                        500, {"error": "internal server error"}, close=True))
                    await writer.drain()
                    done = True
                if done or not req.keep_alive:
                    return
        except (ConnectionResetError, BrokenPipeError, asyncio.CancelledError):
            pass
        finally:
            self.connections -= 1
            writer.close()
            with contextlib.suppress(Exception):
                await writer.wait_closed()

    async def _route(self, req: protocol.HttpRequest,
                     reader: asyncio.StreamReader,
                     writer: asyncio.StreamWriter) -> bool:
        """Dispatch one request; returns True when the connection is done
        (WebSocket sessions own the connection until close)."""
        path = req.path
        if path == "/healthz":
            await self._respond(writer, 200, self._healthz())
            return False
        if path == "/stats":
            await self._respond(writer, 200, self._stats())
            return False
        if path == "/v1/stats/stream":
            await self._stats_stream(req, reader, writer)
            return True
        if path.startswith("/v1/collections/"):
            rest = path[len("/v1/collections/"):]
            name, _, action = rest.partition("/")
            if not name or not action:
                raise _not_found(path)
            if name not in self.router:
                writer.write(protocol.http_response(404, {
                    "error": f"unknown collection {name!r}",
                    "collections": list(self.router.collections()),
                }))
                await writer.drain()
                return False
            if action == "search":
                _require_post(req)
                await self._search(name, req, writer)
                return False
            if action == "upsert":
                _require_post(req)
                await self._upsert(name, req, writer)
                return False
            if action == "delete":
                _require_post(req)
                await self._delete(name, req, writer)
                return False
            if action == "compact":
                await self._compact(name, req, writer)
                return False
        raise _not_found(path)

    async def _respond(self, writer, status, payload, headers=None) -> None:
        writer.write(protocol.http_response(status, payload, headers=headers))
        await writer.drain()

    # ----------------------------------------------------------------- search
    async def _search(self, name: str, req: protocol.HttpRequest,
                      writer: asyncio.StreamWriter) -> None:
        loop = asyncio.get_running_loop()
        engine = self.router.engine(name)
        request, tenant = protocol.parse_search_request(
            req.json(), arrival_s=loop.time(), n_ids=engine.n_ids)
        if request.n_queries() > 1:
            # the continuous batcher IS the batching layer (same contract
            # as AdaptiveScheduler): one query per request, the server
            # amortizes the scan across tenants
            raise protocol.BadRequest(
                "send one query per request (the server batches for you); "
                f"got {request.n_queries()} rows"
            )
        tenant = req.headers.get("x-tenant", tenant)
        batcher = self.batchers[name]
        verdict = self.admission.try_admit(
            tenant, deadline_ms=request.deadline_ms,
            predicted_wait_s=batcher.predicted_wait_s())
        if not verdict.admitted:
            retry_after = max(verdict.retry_after_s, 1e-3)
            await self._respond(
                writer, verdict.status,
                {"error": f"admission rejected: {verdict.reason}",
                 "reason": verdict.reason,
                 "retry_after_ms": retry_after * 1e3},
                headers={"Retry-After": f"{retry_after:.3f}"})
            return
        try:
            fut = batcher.submit(request)
            timeout = (None if self.queue_timeout_ms is None
                       else self.queue_timeout_ms / 1e3)
            try:
                result = await asyncio.wait_for(fut, timeout)
            except asyncio.TimeoutError:
                # wait_for cancelled the future: the batcher drops it at
                # batch-formation time, so the dispatch slot is never spent
                await self._respond(
                    writer, 503,
                    {"error": "request timed out in the serving queue",
                     "reason": "queue_timeout",
                     "retry_after_ms": self.queue_timeout_ms},
                    headers={"Retry-After":
                             f"{self.queue_timeout_ms / 1e3:.3f}"})
                return
            except ServerClosed as e:
                await self._respond(writer, 503, {"error": str(e)})
                return
            except FaultError as e:
                # unrecoverable storage fault under strict semantics (the
                # breaker below threshold stays loud by contract)
                await self._respond(writer, 503, {
                    "error": str(e), "reason": "storage_fault",
                    "shard": getattr(e, "shard_id", -1)})
                return
            except (ValueError, TypeError) as e:
                # engine-level validation the boundary could not see
                # (e.g. int8 tier never enabled on this collection)
                await self._respond(writer, 400, {"error": str(e)})
                return
            await self._respond(writer, 200, protocol.encode_result(result))
        finally:
            self.admission.release(tenant)

    # ------------------------------------------------------------- mutations
    async def _upsert(self, name: str, req: protocol.HttpRequest,
                      writer: asyncio.StreamWriter) -> None:
        payload = req.json()
        if not isinstance(payload, dict) or "vectors" not in payload:
            raise protocol.BadRequest("upsert body must be "
                                      '{"vectors": [[...], ...]}')
        try:
            vec = np.asarray(payload["vectors"], dtype=np.float32)
        except (TypeError, ValueError) as e:
            raise protocol.BadRequest(
                f"'vectors' is not a numeric array: {e}") from None
        if vec.ndim == 1:
            vec = vec[None, :]
        if vec.ndim != 2 or vec.size == 0 or not np.all(np.isfinite(vec)):
            raise protocol.BadRequest(
                f"'vectors' must be a non-empty finite (n, d) matrix, got "
                f"shape {vec.shape}")
        loop = asyncio.get_running_loop()
        try:
            # mutations share the dispatch worker: they serialize with
            # searches, so a search never observes a half-applied upsert
            ids = await loop.run_in_executor(
                self._executor, self.router.upsert, name, vec)
        except (ValueError, TypeError) as e:
            raise protocol.BadRequest(str(e)) from None
        await self._respond(writer, 200,
                            {"ids": np.asarray(ids), "count": len(ids)})

    async def _delete(self, name: str, req: protocol.HttpRequest,
                      writer: asyncio.StreamWriter) -> None:
        payload = req.json()
        if not isinstance(payload, dict) or "ids" not in payload:
            raise protocol.BadRequest('delete body must be {"ids": [...]}')
        try:
            ids = np.asarray(payload["ids"], dtype=np.int64)
        except (TypeError, ValueError) as e:
            raise protocol.BadRequest(
                f"'ids' is not an integer array: {e}") from None
        if ids.ndim != 1 or ids.size == 0:
            raise protocol.BadRequest(
                f"'ids' must be a non-empty flat list, got shape {ids.shape}")
        loop = asyncio.get_running_loop()
        try:
            await loop.run_in_executor(
                self._executor, self.router.delete, name, ids)
        except (ValueError, TypeError, KeyError, IndexError) as e:
            raise protocol.BadRequest(str(e)) from None
        await self._respond(writer, 200, {"deleted": int(ids.size)})

    # ------------------------------------------------------------- compaction
    async def _compact(self, name: str, req: protocol.HttpRequest,
                       writer: asyncio.StreamWriter) -> None:
        """POST triggers compaction of the collection's store; GET reads its
        status. The default trigger is asynchronous (the store's own
        background compactor thread — searches keep streaming their pinned
        generation, so the endpoint returns immediately with the live
        status); ``{"wait": true}`` runs it to completion on the dispatch
        worker (admin tooling, tests) so the response reflects the swap."""
        if req.method == "GET":
            await self._respond(writer, 200,
                                self.router.compaction_status(name))
            return
        _require_post(req)
        payload = req.json() if req.body else {}
        if not isinstance(payload, dict):
            raise protocol.BadRequest(
                'compact body must be a JSON object, e.g. {} or '
                '{"wait": true}')
        wait = bool(payload.get("wait", False))
        loop = asyncio.get_running_loop()
        try:
            if wait:
                # share the dispatch worker: the drain-and-swap then
                # serializes with mutations exactly like upsert/delete
                status = await loop.run_in_executor(
                    self._executor, self.router.compact, name, True)
            else:
                status = self.router.compact(name, False)
        except (ValueError, RuntimeError) as e:
            raise protocol.BadRequest(str(e)) from None
        await self._respond(writer, 200, status)

    # ----------------------------------------------------------------- stats
    def _healthz(self) -> dict:
        out = {"status": "ok", "collections": {}}
        for name, sched in self.schedulers.items():
            st = sched.stats()
            out["collections"][name] = {
                "queue_depth": st["queue_depth"],
                "shed": st["shed"],
                "health": st["health"],
                "circuit_breaker": st["circuit_breaker"],
            }
        return out

    def _stats(self) -> dict:
        return {
            "server": {
                "connections": self.connections,
                "ws_streams": self._ws_streams,
                "queue_timeout_ms": self.queue_timeout_ms,
            },
            "admission": self.admission.stats(),
            "schedulers": {name: sched.stats()
                           for name, sched in self.schedulers.items()},
            "batchers": {name: b.stats()
                         for name, b in self.batchers.items()},
            "router": self.router.stats(),
        }

    async def _stats_stream(self, req: protocol.HttpRequest,
                            reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
        """WebSocket: push scheduler health / phase / breaker stats until
        the client closes. ``?interval_ms=`` overrides the push period."""
        if req.headers.get("upgrade", "").lower() != "websocket":
            raise protocol.BadRequest(
                "/v1/stats/stream requires a WebSocket upgrade")
        key = req.headers.get("sec-websocket-key")
        if not key:
            raise protocol.BadRequest("missing Sec-WebSocket-Key")
        interval_s = self.stats_interval_ms / 1e3
        if "interval_ms" in req.query:
            try:
                interval_ms = float(req.query["interval_ms"])
            except ValueError:
                raise protocol.BadRequest(
                    f"malformed interval_ms={req.query['interval_ms']!r}"
                ) from None
            if interval_ms < 10:
                raise protocol.BadRequest(
                    f"interval_ms must be >= 10, got {interval_ms}")
            interval_s = interval_ms / 1e3
        writer.write(protocol.http_response(
            101, None,
            headers={"Upgrade": "websocket", "Connection": "Upgrade",
                     "Sec-WebSocket-Accept": protocol.ws_accept_key(key)}))
        await writer.drain()
        self._ws_streams += 1
        closer = asyncio.create_task(self._ws_reader(reader, writer))
        try:
            while not closer.done():
                frame = json.dumps(
                    protocol.jsonable(self._stats()), allow_nan=False)
                writer.write(protocol.ws_frame(frame))
                await writer.drain()
                await asyncio.wait([closer], timeout=interval_s)
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            self._ws_streams -= 1
            closer.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await closer

    @staticmethod
    async def _ws_reader(reader, writer) -> None:
        """Consume client frames: answer pings, finish on close/EOF."""
        with contextlib.suppress(protocol.ConnectionClosed,
                                 ConnectionResetError, BrokenPipeError):
            while True:
                opcode, payload = await protocol.ws_read_frame(reader)
                if opcode == protocol.OP_CLOSE:
                    writer.write(protocol.ws_frame(
                        payload, opcode=protocol.OP_CLOSE))
                    await writer.drain()
                    return
                if opcode == protocol.OP_PING:
                    writer.write(protocol.ws_frame(
                        payload, opcode=protocol.OP_PONG))
                    await writer.drain()


def _require_post(req: protocol.HttpRequest) -> None:
    if req.method != "POST":
        err = protocol.ProtocolError(f"{req.path} requires POST")
        err.status = 405
        raise err


def _not_found(path: str) -> protocol.ProtocolError:
    err = protocol.ProtocolError(f"no route for {path!r}")
    err.status = 404
    return err
