"""Data pipelines: synthetic generators + double-buffered device prefetch."""
from repro.data.synthetic import (
    click_log_stream,
    token_stream,
    vector_dataset,
    query_stream,
)
from repro.data.pipeline import DataPipeline

__all__ = [
    "token_stream", "click_log_stream", "vector_dataset", "query_stream",
    "DataPipeline",
]
