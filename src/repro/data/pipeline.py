"""Host->device input pipeline with double-buffered prefetch.

Composes any host iterator (repro.data.synthetic generators, the GNN
neighbor sampler, partition streams) with the paper's double-buffering
schedule (repro.core.streaming.DoubleBufferedStream): batch i+1 transfers
while the device computes on batch i. Optionally shards each batch onto a
mesh (NamedSharding put) so multi-chip training never waits on host I/O.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.streaming import DoubleBufferedStream


class DataPipeline:
    """`host_iter` may be any (re)iterable, including a
    :class:`repro.store.DatasetStore`: a store is a restartable shard
    source (main + live delta, tombstones applied), so
    ``DataPipeline(store)`` supports any number of epochs — each
    ``iter()`` opens a fresh scan of the manifest."""

    def __init__(
        self,
        host_iter: Iterable,
        depth: int = 2,
        mesh: Mesh | None = None,
        specs=None,  # pytree of PartitionSpec matching each batch
        transform: Callable | None = None,
    ):
        self._host = host_iter
        self._depth = depth
        self._mesh = mesh
        self._specs = specs
        self._transform = transform

    def _put(self, batch):
        if self._transform is not None:
            batch = self._transform(batch)
        if self._mesh is None:
            return jax.device_put(batch)
        specs = self._specs or jax.tree.map(lambda _: P(), batch)
        return jax.tree.map(
            lambda x, sp: jax.device_put(x, NamedSharding(self._mesh, sp)),
            batch, specs,
        )

    def __iter__(self) -> Iterator:
        return iter(DoubleBufferedStream(self._host, self._depth, self._put))
