"""Synthetic data generators with controlled statistics.

Real corpora are unavailable offline; these generators reproduce the
STRUCTURE the framework cares about (shapes, dtypes, id distributions,
cluster structure for kNN recall tests) with deterministic seeding.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def token_stream(
    vocab: int, batch: int, seq_len: int, seed: int = 0,
    zipf_a: float = 1.2,
) -> Iterator[dict]:
    """Endless LM batches with a Zipfian token distribution (real-text-like
    marginals so embedding-gather traffic patterns are realistic)."""
    rng = np.random.default_rng(seed)
    while True:
        z = rng.zipf(zipf_a, size=(batch, seq_len + 1))
        tokens = np.minimum(z - 1, vocab - 1).astype(np.int32)
        yield {"tokens": tokens}


def click_log_stream(
    table_sizes: tuple[int, ...], n_dense: int, batch: int, seed: int = 0,
    ctr: float = 0.25,
) -> Iterator[dict]:
    """Recsys impressions: Zipfian categorical ids, log-normal dense
    features, label with a planted logistic signal on feature 0."""
    rng = np.random.default_rng(seed)
    while True:
        dense = rng.lognormal(0.0, 1.0, size=(batch, n_dense)).astype(np.float32)
        cols = []
        for size in table_sizes:
            z = rng.zipf(1.1, size=(batch, 1))
            cols.append(np.minimum(z - 1, size - 1))
        sparse = np.concatenate(cols, axis=1).astype(np.int32)
        logit = 1.5 * np.tanh(dense[:, 0] - 1.0) + rng.normal(0, 1, batch)
        label = (logit > np.quantile(logit, 1 - ctr)).astype(np.float32)
        yield {"dense": dense, "sparse": sparse, "label": label}


def vector_dataset(
    n: int, d: int, n_clusters: int = 64, seed: int = 0, dtype=np.float32
) -> np.ndarray:
    """Clustered vectors (GIST/MSMARCO-like local structure): kNN results
    are dominated by intra-cluster neighbors, which exercises realistic
    score distributions in the queue (many near-ties)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, d)).astype(dtype) * 2.0
    assign = rng.integers(0, n_clusters, n)
    x = centers[assign] + rng.standard_normal((n, d)).astype(dtype) * 0.5
    return x.astype(dtype)


def query_stream(
    dataset: np.ndarray, n_queries: int, seed: int = 0, noise: float = 0.3
) -> np.ndarray:
    """Queries near dataset points (paper's use cases query in-distribution)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, dataset.shape[0], n_queries)
    q = dataset[idx] + rng.standard_normal((n_queries, dataset.shape[1])) * noise
    return q.astype(dataset.dtype)
