"""Pallas TPU kernels for the kNN hot path (validated in interpret mode).

l2dist — MXU-tiled squared-L2 distance matrix (3-stage pipeline analogue)
topk   — streaming top-k over a score matrix (the kNN queue as VMEM scratch)
knn    — fused distance+queue: the paper's full dataflow, distances never
         touch HBM (see kernels/knn/kernel.py header for the traffic math)

Shared: bitonic.py — gather-free compare-exchange networks used by both
queue kernels and usable as plain jnp code.
"""
