"""Jitted public wrappers for the fused kNN kernels (engine backend="pallas").

Three entry points:

* :func:`knn`              — fused f32/bf16 scan (l2 | ip | cos). cos is
                             served by pre-normalizing rows and reusing the
                             ip epilogue (1 - <q_hat, x_hat>), so every
                             metric runs through one kernel family.
* :func:`knn_int8`         — fused int8 scan (1 B/element dataset traffic)
                             with an on-chip widened candidate queue and a
                             certified exact f32 rescore that reads only
                             the candidate rows.
* :func:`knn_exact_direct` — chunked exact scan in the direct (q - x)^2
                             form; the bit-exact oracle/fallback for the
                             quantized path (per-pair values are identical
                             to a full-sort oracle using the same formula).

All wrappers handle padding; `block_*` arguments come from the per-device
autotuner (``repro.tuning``) via the planner, defaulting to
:data:`DEFAULT_BLOCKS`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.partition import next_pow2
from repro.core.quantized import QuantizedDataset
from repro.core.topk import TopK, sort_pairs
from repro.kernels.knn.kernel import knn_pallas
from repro.kernels.knn.kernel_int8 import knn_pallas_int8

#: Hand-tuned fallback (bm, bn, bd) used when the autotune cache is cold.
DEFAULT_BLOCKS = (128, 512, 512)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def resolved_blocks(
    k: int,
    d: int,
    block_m: int = DEFAULT_BLOCKS[0],
    block_n: int = DEFAULT_BLOCKS[1],
    block_d: int = DEFAULT_BLOCKS[2],
    rescore_factor: int | None = None,
) -> tuple[int, int, int]:
    """The (bm, bn, bd) the kernels ACTUALLY run after legality clamps:
    bn grows to hold the on-chip queue, bd shrinks to the padded dim.

    Single source of truth — :func:`knn` / :func:`knn_int8` resolve their
    tiles through this, and the executors call it to report honest tile
    shapes in kernel_stats. ``rescore_factor=None`` means the f32 kernel
    (queue width k_eff); an int means the int8 kernel (queue width
    2 x next_pow2(rescore_factor * k_eff))."""
    k_eff = next_pow2(k)
    if rescore_factor is None:
        queue = k_eff
    else:
        queue = 2 * next_pow2(max(1, rescore_factor) * k_eff)
    return (block_m, max(block_n, queue),
            min(block_d, _round_up(max(d, 1), 128)))


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "metric", "block_m", "block_n", "block_d", "interpret",
        "prune", "return_stats", "x_prenormalized",
    ),
)
def knn(
    q: jax.Array,
    x: jax.Array,
    k: int,
    metric: str = "l2",
    x_norms: jax.Array | None = None,
    block_m: int = 128,
    block_n: int = 512,
    block_d: int = 512,
    interpret: bool | None = None,
    prune: bool = True,
    return_stats: bool = False,
    x_prenormalized: bool = False,
):
    """Exact kNN of (M, d) queries over (N, d) dataset -> TopK((M,k),(M,k)).

    Handles all padding: d zero-padded (exact for both metrics), N padded
    with +inf-norm rows (excluded by the in-kernel validity mask), k rounded
    to a power of two for the bitonic queue then sliced. If `x_norms` is
    given (engine-resident datasets precompute them) padded entries must
    already be +inf.

    metric="cos" pre-normalizes query and dataset rows (zero rows stay
    zero, matching `cosine_distance`'s "distance 1" convention) and reuses
    the ip epilogue: cos(q, x) distance = 1 + (-<q_hat, x_hat>). The +1
    shift is monotonic, so ordering and tie-breaking are untouched.
    Normalizing the dataset is an O(N*d) pass, so engines that serve cos
    from a resident view normalize it ONCE at fit time (cos is
    scale-invariant) and pass `x_prenormalized=True`; then only the (M, d)
    queries are normalized per call. `x_norms` stays the raw-norm validity
    channel (+inf = padding/tombstone) either way.

    `prune` enables the threshold-pruned queue merge (bit-identical results
    either way; see kernel.py). With `return_stats=True` the result is
    (TopK, skip_rate) where skip_rate is the fraction of tile merges the
    insertion filter skipped.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if metric not in ("l2", "ip", "cos"):
        raise ValueError(f"fused kernel supports l2|ip|cos, got {metric}")
    if x_norms is None:
        xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    else:
        xn = x_norms.astype(jnp.float32)

    kernel_metric = metric
    if metric == "cos":
        # pre-normalize rows, reuse the ip epilogue. Zero rows (norm 0) and
        # padded rows (norm +inf) both normalize to zero vectors -> ip 0 ->
        # distance 1; padded rows are additionally masked by xn = +inf.
        q32 = q.astype(jnp.float32)
        qn_row = jnp.sqrt(jnp.sum(q32 * q32, axis=-1, keepdims=True))
        q = jnp.where(qn_row > 0, q32 / jnp.maximum(qn_row, 1e-30), 0.0)
        if not x_prenormalized:
            x32 = x.astype(jnp.float32)
            xn_row = jnp.sqrt(xn)[:, None]
            x = jnp.where(
                jnp.isfinite(xn_row) & (xn_row > 0),
                x32 / jnp.maximum(xn_row, 1e-30), 0.0,
            )
        else:
            # resident pre-normalized views keep their storage dtype; the
            # fused kernel requires q/x to share one dtype, so follow x
            q = q.astype(x.dtype)
        kernel_metric = "ip"

    m, d = q.shape
    n, _ = x.shape
    k_eff = next_pow2(k)
    bm, bn, bd = resolved_blocks(k, d, block_m, block_n, block_d)
    mp, np_, dp = _round_up(m, bm), _round_up(n, bn), _round_up(d, bd)

    qp = jnp.pad(q, ((0, mp - m), (0, dp - d)))
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    xn = jnp.pad(xn, (0, np_ - n), constant_values=jnp.inf)[None, :]

    v, i, skips = knn_pallas(qp, xp, xn, k_eff, kernel_metric, bm, bn, bd,
                             interpret, prune)
    v, i = v[:m, :k], i[:m, :k]
    if metric == "cos":
        v = v + 1.0  # -<q_hat, x_hat> -> cosine distance (+inf stays +inf)
    out = TopK(v, jnp.where(jnp.isfinite(v), i, -1))
    if not return_stats:
        return out
    merges = (mp // bm) * (np_ // bn)
    skip_rate = jnp.sum(skips).astype(jnp.float32) / merges
    return out, skip_rate


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "rescore_factor", "block_m", "block_n", "block_d", "interpret",
        "prune", "return_stats",
    ),
)
def knn_int8(
    q: jax.Array,
    ds: QuantizedDataset,
    full_vectors: jax.Array,
    k: int,
    rescore_factor: int = 4,
    block_m: int = 128,
    block_n: int = 512,
    block_d: int = 512,
    interpret: bool | None = None,
    prune: bool = True,
    return_stats: bool = False,
):
    """Exact kNN with a fused int8 first pass and a candidate-only rescore.

    The int8 scan (``knn_pallas_int8``) keeps a widened on-chip queue of
    q_len = 2r certified lower bounds per query, r = next_pow2(
    rescore_factor * next_pow2(k)). The epilogue here:

    1. gathers ONLY the r candidate rows from `full_vectors` and rescores
       them exactly in f32 via the direct (q - x)^2 form — bit-identical to
       :func:`knn_exact_direct` / a full-sort oracle over the same rows;
    2. certifies: every row outside the candidate set has lower bound
       >= the queue's (r+1)-th entry; if that exceeds the k-th smallest
       *exact* candidate distance, no outside row can reach the top-k, so
       the returned top-k is provably the global exact answer.

    Returns (TopK, certificate (m,) bool), plus the pruning skip rate when
    `return_stats=True`. Requires q, ds.q and full_vectors to share one
    (padded) feature width, and ds.q / full_vectors one row count — the
    DatasetStore guarantees both for its tier views.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, d = q.shape
    n, d8 = ds.q.shape
    if d != d8 or full_vectors.shape != (n, d8):
        raise ValueError(
            f"geometry mismatch: q {q.shape}, int8 {ds.q.shape}, "
            f"f32 {full_vectors.shape} (tiers must share padded shapes)"
        )
    k_eff = next_pow2(k)
    r = next_pow2(max(1, rescore_factor) * k_eff)
    q_len = 2 * r
    bm, bn, bd = resolved_blocks(k, d, block_m, block_n, block_d,
                                 rescore_factor=rescore_factor)
    mp, np_, dp = _round_up(m, bm), _round_up(n, bn), _round_up(d, bd)

    q32 = q.astype(jnp.float32)
    qp = jnp.pad(q32, ((0, mp - m), (0, dp - d)))
    x8 = jnp.pad(ds.q, ((0, np_ - n), (0, dp - d)))
    qn = jnp.sum(qp * qp, axis=-1, keepdims=True)
    scales = jnp.pad(ds.scales.astype(jnp.float32), (0, np_ - n),
                     constant_values=1.0)[None, :]
    err = jnp.pad(ds.err.astype(jnp.float32), (0, np_ - n))[None, :]
    # validity rides norms_sq (+inf on tombstones; the only channel
    # mutations refresh), folded onto the exact quantized norms the
    # kernel's certified bound requires
    hn = jnp.where(jnp.isfinite(ds.norms_sq),
                   ds.qnorm_sq.astype(jnp.float32), jnp.inf)
    hn = jnp.pad(hn, (0, np_ - n), constant_values=jnp.inf)[None, :]

    lb, li, skips = knn_pallas_int8(qp, x8, qn, scales, err, hn, q_len,
                                    bm, bn, bd, interpret, prune)
    lb, li = lb[:m], li[:m]

    # certified exact rescore: read only the candidate rows of the f32 tier
    cand_idx = li[:, :r]
    cand_ok = cand_idx >= 0  # unfilled queue slots stay (inf, -1)
    cand_vecs = full_vectors[jnp.where(cand_ok, cand_idx, 0)]
    diff = q32[:, None, :] - cand_vecs.astype(jnp.float32)
    exact_d = jnp.sum(diff * diff, axis=-1)
    exact_d = jnp.where(cand_ok, exact_d, jnp.inf)
    s, i = sort_pairs(exact_d, cand_idx)  # lexicographic: exact tie order
    s, i = s[:, :k], i[:, :k]
    i = jnp.where(jnp.isfinite(s), i, -1)

    # certificate: min lower bound OUTSIDE the candidate set (= queue entry
    # r) must exceed the k-th smallest exact candidate distance; an
    # infinite entry means the candidates already hold every valid row.
    thresh = s[:, k - 1]
    lb_r1 = lb[:, r]
    certificate = (lb_r1 > thresh) | ~jnp.isfinite(lb_r1)

    out = TopK(s, i)
    if not return_stats:
        return out, certificate
    merges = (mp // bm) * (np_ // bn)
    skip_rate = jnp.sum(skips).astype(jnp.float32) / merges
    return out, certificate, skip_rate


@functools.partial(jax.jit, static_argnames=("k", "chunk_rows"))
def knn_exact_direct(
    q: jax.Array,
    x: jax.Array,
    norms: jax.Array,
    k: int,
    chunk_rows: int = 8192,
) -> TopK:
    """Chunked exact kNN in the DIRECT (q - x)^2 form (l2 only).

    Unlike `fqsd_scan` (which uses the qn - 2qx + xn cancellation form),
    every pairwise distance here is the literal f32 sum of squared
    differences — the same value, bit for bit, that `knn_int8`'s candidate
    rescore computes. Chunked merging is lexicographic ((value, index)
    sort), so the result is identical to a full-sort oracle over the same
    formula regardless of chunking: this is the exactness fallback for
    uncertified int8 queries AND the oracle the int8 tests compare against.

    `norms` carries the validity channel (+inf on padding/tombstones);
    N must be a multiple of chunk_rows (pad first).
    """
    m = q.shape[0]
    n, d = x.shape
    if n % chunk_rows:
        raise ValueError(f"N={n} not a multiple of chunk_rows={chunk_rows}")
    q32 = q.astype(jnp.float32)
    c = n // chunk_rows
    chunks = x.reshape(c, chunk_rows, d)
    norm_chunks = norms.reshape(c, chunk_rows)
    bases = jnp.arange(c, dtype=jnp.int32) * chunk_rows

    def body(state, xs):
        chunk, nb, base = xs
        diff = q32[:, None, :] - chunk[None, :, :].astype(jnp.float32)
        dmat = jnp.sum(diff * diff, axis=-1)
        dmat = jnp.where(jnp.isfinite(nb)[None, :], dmat, jnp.inf)
        idx = base + jnp.arange(chunk_rows, dtype=jnp.int32)
        idx = jnp.broadcast_to(idx[None, :], dmat.shape)
        s_all = jnp.concatenate([state[0], dmat], axis=-1)
        i_all = jnp.concatenate([state[1], idx], axis=-1)
        s, i = sort_pairs(s_all, i_all)
        return (s[:, :k], i[:, :k]), None

    init = (jnp.full((m, k), jnp.inf, jnp.float32),
            jnp.full((m, k), -1, jnp.int32))
    (s, i), _ = jax.lax.scan(body, init, (chunks, norm_chunks, bases))
    return TopK(s, jnp.where(jnp.isfinite(s), i, -1))
