"""Jitted public wrapper for the fused kNN kernel (engine backend="pallas")."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.partition import next_pow2
from repro.core.topk import TopK
from repro.kernels.knn.kernel import knn_pallas


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "block_m", "block_n", "block_d", "interpret"),
)
def knn(
    q: jax.Array,
    x: jax.Array,
    k: int,
    metric: str = "l2",
    x_norms: jax.Array | None = None,
    block_m: int = 128,
    block_n: int = 512,
    block_d: int = 512,
    interpret: bool | None = None,
) -> TopK:
    """Exact kNN of (M, d) queries over (N, d) dataset -> TopK((M,k),(M,k)).

    Handles all padding: d zero-padded (exact for both metrics), N padded
    with +inf-norm rows (excluded by the in-kernel validity mask), k rounded
    to a power of two for the bitonic queue then sliced. If `x_norms` is
    given (engine-resident datasets precompute them) padded entries must
    already be +inf.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if metric not in ("l2", "ip"):
        raise ValueError(f"fused kernel supports l2|ip, got {metric}")
    m, d = q.shape
    n, _ = x.shape
    k_eff = next_pow2(k)
    bn = max(block_n, k_eff)
    bm, bd = block_m, min(block_d, _round_up(d, 128))
    mp, np_, dp = _round_up(m, bm), _round_up(n, bn), _round_up(d, bd)

    qp = jnp.pad(q, ((0, mp - m), (0, dp - d)))
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    if x_norms is None:
        xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    else:
        xn = x_norms.astype(jnp.float32)
    xn = jnp.pad(xn, (0, np_ - n), constant_values=jnp.inf)[None, :]

    v, i = knn_pallas(qp, xp, xn, k_eff, metric, bm, bn, bd, interpret)
    v, i = v[:m, :k], i[:m, :k]
    return TopK(v, jnp.where(jnp.isfinite(v), i, -1))
