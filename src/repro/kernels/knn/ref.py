"""Pure-jnp oracle for the fused kNN kernel."""
import jax
import jax.numpy as jnp


def knn_ref(
    q: jax.Array, x: jax.Array, k: int, metric: str = "l2"
) -> tuple[jax.Array, jax.Array]:
    """Exact kNN from dense scores. (M, d), (N, d) -> (M, k) vals + idx."""
    q32, x32 = q.astype(jnp.float32), x.astype(jnp.float32)
    cross = q32 @ x32.T
    if metric == "l2":
        qn = jnp.sum(q32 * q32, axis=-1, keepdims=True)
        xn = jnp.sum(x32 * x32, axis=-1)
        s = jnp.maximum(qn - 2.0 * cross + xn[None, :], 0.0)
    elif metric == "ip":
        s = -cross
    elif metric == "cos":
        qn = jnp.sqrt(jnp.sum(q32 * q32, axis=-1, keepdims=True))
        xn = jnp.sqrt(jnp.sum(x32 * x32, axis=-1))[None, :]
        s = 1.0 - cross / jnp.maximum(qn * xn, 1e-30)
    else:
        raise ValueError(metric)
    m, n = s.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (m, n))
    sv, si = jax.lax.sort((s, idx), dimension=-1, num_keys=2)
    if n >= k:
        return sv[:, :k], si[:, :k]
    pad = k - n
    sv = jnp.concatenate([sv, jnp.full((m, pad), jnp.inf, jnp.float32)], axis=1)
    si = jnp.concatenate([si, jnp.full((m, pad), -1, jnp.int32)], axis=1)
    return sv, si
