"""Fused exact-kNN Pallas kernel — the paper's whole dataflow in one pass.

On the FPGA, distances flow from the distance-computation pipelines straight
into the kNN queues; the (M, N) distance matrix never exists in memory. This
kernel reproduces that property on TPU: per grid step it computes one
(bm, bn) score tile on the MXU (accumulated over d blocks, like the
vector-adder) and immediately folds it into the VMEM-resident per-query
queues (bitonic top-k update). HBM traffic is exactly

    M*d + N*d (+ M*k out)   instead of   M*d + N*d + M*N

— for GIST (M=1e3, N=1e6) that removes a 4 GB intermediate; it converts the
operation from memory-bound to MXU-bound for any M >= ~6 (see roofline).

Grid (m_tiles, n_tiles, d_tiles): d innermost accumulates cross-products
into an f32 VMEM accumulator; on the last d step the tile is scored
(norm epilogue or negated IP), sorted, and merged into the queue scratch;
queues flush to HBM on the last (n, d) step. The sequential-grid input
pipelining (next (Q, X) tiles DMA while current tile computes) is the
paper's double-buffering at the VMEM tier.

Threshold-pruned queue merge (``prune=True``, the default): the queue
scratch ``buf_v`` is sorted ascending, so its last column is each query's
current kth-best score. Before sorting a tile, the kernel computes the
tile's row-wise minimum; when EVERY query's tile minimum is strictly worse
than its kth-best, no candidate in the tile can enter any queue and the
bitonic sort + merge are skipped (``repro.kernels.bitonic.tile_prunable``).

**Pruning invariant**: the skip test uses strict ``>``. A candidate that
ties the queue's worst value can still displace it via the lexicographic
(value, index) tie-break, so tying tiles are never pruned — the pruned
kernel is bit-identical (values AND indices) to the unpruned kernel on
every input, including tie-heavy ones (tested by tests/test_int8_pallas.py).
This is the paper's insertion filter: once queues warm up, the per-tile
sort runs rarely instead of always. Skipped-merge counts are emitted per
m-tile in the third output so callers can report the measured skip rate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.kernels.bitonic import bitonic_sort, tile_prunable, topk_update


def _knn_kernel(
    q_ref, x_ref, qn_ref, xn_ref, ov_ref, oi_ref, sk_ref, acc, buf_v, buf_i,
    *, k_eff: int, n_steps: int, d_steps: int, bn: int, metric: str,
    prune: bool,
):
    j = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_queue():
        buf_v[...] = jnp.full_like(buf_v, jnp.inf)
        buf_i[...] = jnp.full_like(buf_i, -1)
        sk_ref[0, 0] = 0

    @pl.when(kd == 0)
    def _init_acc():
        acc[...] = jnp.zeros_like(acc)

    # partial-distance / vector-adder: MXU cross-product accumulation
    acc[...] += lax.dot_general(
        q_ref[...], x_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kd == d_steps - 1)
    def _score_and_enqueue():
        cross = acc[...]
        xn = xn_ref[...]  # (1, bn); +inf marks padded rows
        valid = jnp.isfinite(xn)
        if metric == "l2":
            s = jnp.maximum(qn_ref[...] - 2.0 * cross + xn, 0.0)
        else:  # ip
            s = -cross
        s = jnp.where(valid, s, jnp.inf)
        idx = j * bn + lax.broadcasted_iota(jnp.int32, s.shape, 1)

        def _merge():
            sv, si = bitonic_sort(s, idx)
            buf_v[...], buf_i[...] = topk_update(
                buf_v[...], buf_i[...], sv[:, :k_eff], si[:, :k_eff]
            )

        if prune:
            # kNN-queue insertion filter: sort+merge only when some row of
            # the tile can still beat that query's kth-best (strict >; ties
            # never prune — see module docstring).
            skip = tile_prunable(s, buf_v[...])
            pl.when(~skip)(_merge)

            @pl.when(skip)
            def _count_skip():
                sk_ref[0, 0] += 1
        else:
            _merge()

    @pl.when((j == n_steps - 1) & (kd == d_steps - 1))
    def _flush():
        ov_ref[...] = buf_v[...]
        oi_ref[...] = buf_i[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "k_eff", "metric", "block_m", "block_n", "block_d", "interpret",
        "prune",
    ),
)
def knn_pallas(
    q: jax.Array,
    x: jax.Array,
    xn: jax.Array,
    k_eff: int,
    metric: str = "l2",
    block_m: int = 128,
    block_n: int = 512,
    block_d: int = 512,
    interpret: bool = False,
    prune: bool = True,
):
    """Fused exact kNN. Preconditions enforced by ops.py:
    M % bm == N % bn == d % bd == 0; k_eff pow2 <= bn; xn is (1, N) with
    +inf on padded rows; q/x same dtype.

    Returns (values (M, k_eff), indices (M, k_eff), skips (m_tiles, 1)):
    `skips` counts threshold-pruned tile merges per m-tile (each m-tile has
    exactly n_tiles merge opportunities).
    """
    m, d = q.shape
    n, _ = x.shape
    bm, bn, bd = block_m, block_n, block_d
    if m % bm or n % bn or d % bd or k_eff > bn:
        raise ValueError(f"bad blocking m{m} n{n} d{d} bm{bm} bn{bn} bd{bd} k{k_eff}")
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    n_steps, d_steps = n // bn, d // bd
    grid = (m // bm, n_steps, d_steps)
    kern = functools.partial(
        _knn_kernel, k_eff=k_eff, n_steps=n_steps, d_steps=d_steps, bn=bn,
        metric=metric, prune=prune,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bn, bd), lambda i, j, kd: (j, kd)),
            pl.BlockSpec((bm, 1), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kd: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k_eff), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((bm, k_eff), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kd: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((m, k_eff), jnp.int32),
            jax.ShapeDtypeStruct((m // bm, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),  # cross-product accumulator
            pltpu.VMEM((bm, k_eff), jnp.float32),  # queue values
            pltpu.VMEM((bm, k_eff), jnp.int32),  # queue indices
        ],
        compiler_params=compat.tpu_compiler_params(
            ('parallel', 'arbitrary', 'arbitrary')
        ),
        interpret=interpret,
    )(q, x, qn, xn)
