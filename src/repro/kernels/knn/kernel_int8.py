"""Fused int8 Pallas scan — 1 B/element dataset traffic, on-chip candidates.

The paper's FQ-SD throughput ceiling is memory bandwidth (section 5 names
quantization as the lever), so the int8 tier's whole point is bytes moved:
this kernel streams the int8 codes from HBM at 1 byte/element and never
materializes any (M, N) intermediate. Per grid step it

1. dot-accumulates one quantized (bm, bn) cross-product tile on the MXU
   into an f32 VMEM accumulator (the int8 tile is widened in VMEM, so HBM
   sees only the 1-byte codes);
2. applies the per-row scale dequant in the epilogue, forms the EXACT
   quantized-approximation distance d_hat = ||q - x_hat||^2 from the
   stored quantized norms, and lower-bounds the true squared-L2 distance
   with the ``repro.core.quantized`` reverse-triangle bound
   max(sqrt(d_hat) - err, 0)^2;
3. folds the tile's lower bounds into a VMEM-resident *widened* candidate
   queue of q_len = 2 * (rescore_budget) entries per query — wide so the
   caller can read both the rescore candidates (first half) and the
   (r+1)-th smallest lower bound that certifies them (entry r).

The certified exact rescore then happens outside the kernel and reads ONLY
the candidate rows of the f32 base tier (an (M, r) gather instead of a full
4 B/element pass) — see ``repro.kernels.knn.ops.knn_int8``.

The threshold-pruned queue merge is shared with the f32 kernel: strictly
worse tiles skip the bitonic sort + merge (strict ``>`` keeps pruning
bit-identical under ties — see ``kernel.py`` for the invariant), and
skipped-merge counts flush per m-tile for skip-rate reporting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.kernels.bitonic import bitonic_sort, tile_prunable, topk_update


def _knn_int8_kernel(
    q_ref, x_ref, qn_ref, sc_ref, er_ref, hn_ref, ov_ref, oi_ref, sk_ref,
    acc, buf_v, buf_i,
    *, q_len: int, n_steps: int, d_steps: int, bn: int, prune: bool,
):
    j = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when((j == 0) & (kd == 0))
    def _init_queue():
        buf_v[...] = jnp.full_like(buf_v, jnp.inf)
        buf_i[...] = jnp.full_like(buf_i, -1)
        sk_ref[0, 0] = 0

    @pl.when(kd == 0)
    def _init_acc():
        acc[...] = jnp.zeros_like(acc)

    # int8 codes widen in VMEM; HBM traffic for the dataset stays 1 B/elem
    acc[...] += lax.dot_general(
        q_ref[...], x_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kd == d_steps - 1)
    def _bound_and_enqueue():
        # per-row scale dequant epilogue: <q, x_hat> = s_x * <q, q_x>
        cross = acc[...] * sc_ref[...]  # (bm, bn) * (1, bn)
        hn = hn_ref[...]  # (1, bn) exact ||x_hat||^2; +inf marks invalid rows
        e = er_ref[...]  # (1, bn) certified ||e_x|| upper bound
        valid = jnp.isfinite(hn)
        # d_hat = ||q - x_hat||^2 EXACTLY (inf-safe on invalid rows), so the
        # reverse-triangle bound (sqrt(d_hat) - err)^2 <= d is sound; an
        # approximated quantized norm would drop the 2<x_hat, e> cross term
        # and overshoot the bound past true distances (see core.quantized)
        d_hat = jnp.maximum(
            qn_ref[...] - 2.0 * cross + jnp.where(valid, hn, 0.0), 0.0
        )
        lower = jnp.where(
            valid, jnp.maximum(jnp.sqrt(d_hat) - e, 0.0) ** 2, jnp.inf
        )
        idx = j * bn + lax.broadcasted_iota(jnp.int32, lower.shape, 1)

        def _merge():
            sv, si = bitonic_sort(lower, idx)
            buf_v[...], buf_i[...] = topk_update(
                buf_v[...], buf_i[...], sv[:, :q_len], si[:, :q_len]
            )

        if prune:
            skip = tile_prunable(lower, buf_v[...])
            pl.when(~skip)(_merge)

            @pl.when(skip)
            def _count_skip():
                sk_ref[0, 0] += 1
        else:
            _merge()

    @pl.when((j == n_steps - 1) & (kd == d_steps - 1))
    def _flush():
        ov_ref[...] = buf_v[...]
        oi_ref[...] = buf_i[...]


@functools.partial(
    jax.jit,
    static_argnames=(
        "q_len", "block_m", "block_n", "block_d", "interpret", "prune",
    ),
)
def knn_pallas_int8(
    q: jax.Array,
    x8: jax.Array,
    qn: jax.Array,
    scales: jax.Array,
    err: jax.Array,
    hn: jax.Array,
    q_len: int,
    block_m: int = 128,
    block_n: int = 512,
    block_d: int = 512,
    interpret: bool = False,
    prune: bool = True,
):
    """Fused int8 candidate scan. Preconditions enforced by ops.py:
    M % bm == N % bn == d % bd == 0; q_len pow2 <= bn; q f32, x8 int8;
    scales/err/hn are (1, N) f32 with hn the EXACT quantized norm
    ||x_hat||^2 = s^2 * sum(q_x^2), set to +inf on invalid rows (padding /
    tombstones); err = 0 and scales = 1 on padding.

    Returns (lower bounds (M, q_len) sorted ascending, indices (M, q_len),
    skips (m_tiles, 1)). The first q_len//2 columns are the rescore
    candidates; column q_len//2 is the (r+1)-th smallest lower bound used
    by the exactness certificate.
    """
    m, d = q.shape
    n, _ = x8.shape
    bm, bn, bd = block_m, block_n, block_d
    if m % bm or n % bn or d % bd or q_len > bn:
        raise ValueError(
            f"bad blocking m{m} n{n} d{d} bm{bm} bn{bn} bd{bd} q_len{q_len}"
        )
    n_steps, d_steps = n // bn, d // bd
    grid = (m // bm, n_steps, d_steps)
    kern = functools.partial(
        _knn_int8_kernel, q_len=q_len, n_steps=n_steps, d_steps=d_steps,
        bn=bn, prune=prune,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bn, bd), lambda i, j, kd: (j, kd)),
            pl.BlockSpec((bm, 1), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kd: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kd: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kd: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, q_len), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((bm, q_len), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kd: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, q_len), jnp.float32),
            jax.ShapeDtypeStruct((m, q_len), jnp.int32),
            jax.ShapeDtypeStruct((m // bm, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),  # int32->f32 cross accumulator
            pltpu.VMEM((bm, q_len), jnp.float32),  # candidate lower bounds
            pltpu.VMEM((bm, q_len), jnp.int32),  # candidate indices
        ],
        compiler_params=compat.tpu_compiler_params(
            ('parallel', 'arbitrary', 'arbitrary')
        ),
        interpret=interpret,
    )(q, x8, qn, scales, err, hn)
