from repro.kernels.knn.ops import knn
