from repro.kernels.knn.ops import (
    DEFAULT_BLOCKS,
    knn,
    knn_exact_direct,
    knn_int8,
)
