"""Jitted public wrapper for the l2dist kernel: padding + backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.l2dist.kernel import l2dist_pallas
from repro.kernels.l2dist.ref import l2dist_ref


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_d", "interpret")
)
def l2dist(
    q: jax.Array,
    x: jax.Array,
    block_m: int = 256,
    block_n: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Squared L2 distance matrix via the Pallas kernel, any (M, N, d).

    Inputs are zero-padded to block multiples (zero pads contribute 0 to all
    three terms, so the valid region is exact); the result is sliced back.
    interpret=None auto-selects interpret mode off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, d = q.shape
    n, _ = x.shape
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 128))
    bd = min(block_d, _round_up(d, 128))
    mp, np_, dp = _round_up(m, bm), _round_up(n, bn), _round_up(d, bd)
    qp = jnp.pad(q, ((0, mp - m), (0, dp - d)))
    xp = jnp.pad(x, ((0, np_ - n), (0, dp - d)))
    out = l2dist_pallas(qp, xp, bm, bn, bd, interpret)
    return out[:m, :n]


def l2dist_reference(q: jax.Array, x: jax.Array) -> jax.Array:
    return l2dist_ref(q, x)
