"""MXU-tiled squared-L2 distance kernel (Pallas TPU).

TPU adaptation of the paper's `distance-computation` pipeline (section 3.3):
the partial-distance / vector-adder / full-adder chain becomes a blocked
GEMM with a fused norm epilogue:

    D[i, j] = ||q_i||^2 - 2 <q_i, x_j> + ||x_j||^2

Grid: (M/bm, N/bn, d/bd). The d axis is the innermost ("arbitrary") grid
dimension; partial cross-products accumulate into the output tile across d
steps — exactly the vector-adder's B += A accumulation, with the MXU doing
w=128-wide partial distances per pass. Norm epilogue is applied on the last
d step (the full-adder).

VMEM per step: bm*bd + bn*bd + bm*bn floats. Defaults (bm=bn=256, bd=512)
-> 0.5 MB + 0.5 MB + 0.25 MB, comfortably double-bufferable in 16 MB VMEM
(Pallas pipelines the next (Q, X) tiles while the MXU consumes the current
ones — the kernel-level analogue of the paper's two memory banks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _l2dist_kernel(q_ref, x_ref, qn_ref, xn_ref, o_ref, *, n_d_steps: int):
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # partial-distance + vector-adder: accumulate -2 * Q X^T over d blocks
    q = q_ref[...]
    x = x_ref[...]
    part = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += -2.0 * part

    # full-adder epilogue: add norms once, on the final d step
    @pl.when(kd == n_d_steps - 1)
    def _epilogue():
        acc = o_ref[...]
        acc = acc + qn_ref[...] + xn_ref[...]
        o_ref[...] = jnp.maximum(acc, 0.0)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_d", "interpret")
)
def l2dist_pallas(
    q: jax.Array,
    x: jax.Array,
    block_m: int = 256,
    block_n: int = 256,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(M, d) x (N, d) -> (M, N) squared L2. Dims must divide by blocks."""
    m, d = q.shape
    n, dx = x.shape
    assert d == dx, (d, dx)
    bm, bn, bd = min(block_m, m), min(block_n, n), min(block_d, d)
    if m % bm or n % bn or d % bd:
        raise ValueError(f"shape ({m},{n},{d}) not divisible by blocks ({bm},{bn},{bd})")
    if q.dtype != x.dtype:
        raise ValueError(f"operand dtypes must match, got {q.dtype} vs {x.dtype}")
    qn = jnp.sum(q.astype(jnp.float32) ** 2, axis=-1, keepdims=True)  # (M, 1)
    xn = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True).T  # (1, N)
    n_d_steps = d // bd

    grid = (m // bm, n // bn, n_d_steps)
    return pl.pallas_call(
        functools.partial(_l2dist_kernel, n_d_steps=n_d_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bn, bd), lambda i, j, kd: (j, kd)),
            pl.BlockSpec((bm, 1), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kd: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            ('parallel', 'parallel', 'arbitrary')
        ),
        interpret=interpret,
    )(q, x, qn, xn)
