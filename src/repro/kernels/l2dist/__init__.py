from repro.kernels.l2dist.ops import l2dist
