"""Pure-jnp oracle for the l2dist kernel."""
import jax
import jax.numpy as jnp


def l2dist_ref(q: jax.Array, x: jax.Array) -> jax.Array:
    """Squared euclidean distance matrix, (M, d) x (N, d) -> (M, N) f32."""
    q32 = q.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    qn = jnp.sum(q32 * q32, axis=-1, keepdims=True)
    xn = jnp.sum(x32 * x32, axis=-1)
    cross = q32 @ x32.T
    return jnp.maximum(qn - 2.0 * cross + xn[None, :], 0.0)
