"""Vectorized bitonic networks — lane-parallel compare-exchange primitives.

The FPGA kNN queue is a chain of k compare-swap nodes processing one element
per cycle. The VPU is an (8 x 128)-lane SIMD machine, so the element-serial
queue becomes O(log^2) *stages* of full-width compare-exchanges. Everything
here is written with roll/iota/where only (no gathers, no lane reshapes) so
it lowers inside Pallas TPU kernels; the same functions double as jnp
reference code.

All arrays are (..., L) with L a power of two; (values, indices) move as
pairs and comparisons are lexicographic (value, index) so exact-score ties
break to the smaller index — identical semantics to the systolic queue's
stable drain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _is_pow2(x: int) -> bool:
    return x > 0 and (x & (x - 1)) == 0


def _pair_less(v1, i1, v2, i2):
    """(v1, i1) < (v2, i2) lexicographically."""
    return (v1 < v2) | ((v1 == v2) & (i1 < i2))


def _compare_exchange(vals, idxs, s: int, take_smaller):
    """One compare-exchange stage at XOR-distance s (s a power of two).

    take_smaller : bool array broadcastable to vals — True where this lane
    keeps the smaller of (self, partner). Partner of lane i is lane i^s,
    realized with two rolls + a bit mask (gather-free).
    """
    lane = lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    upper = (lane & s) != 0  # I am the +s element of my pair
    fwd_v = jnp.roll(vals, -s, axis=-1)  # vals[i+s]
    bwd_v = jnp.roll(vals, s, axis=-1)  # vals[i-s]
    fwd_i = jnp.roll(idxs, -s, axis=-1)
    bwd_i = jnp.roll(idxs, s, axis=-1)
    part_v = jnp.where(upper, bwd_v, fwd_v)
    part_i = jnp.where(upper, bwd_i, fwd_i)
    partner_smaller = _pair_less(part_v, part_i, vals, idxs)
    choose_partner = jnp.where(take_smaller, partner_smaller, ~partner_smaller)
    out_v = jnp.where(choose_partner, part_v, vals)
    out_i = jnp.where(choose_partner, part_i, idxs)
    return out_v, out_i


def bitonic_sort(vals, idxs):
    """Full ascending bitonic sort over the last axis (length power of two).

    log^2(L) compare-exchange stages, each O(L) vectorized work across all
    leading axes — the throughput-form of the paper's one-element-per-cycle
    queue insertion.
    """
    L = vals.shape[-1]
    if not _is_pow2(L):
        raise ValueError(f"bitonic_sort needs power-of-two length, got {L}")
    lane = lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    k = 2
    while k <= L:
        asc = (lane & k) == 0  # block direction alternates at span k
        s = k // 2
        while s >= 1:
            lower = (lane & s) == 0
            take_smaller = lower == asc
            vals, idxs = _compare_exchange(vals, idxs, s, take_smaller)
            s //= 2
        k *= 2
    return vals, idxs


def bitonic_merge_ascending(vals, idxs):
    """Sort a *bitonic* (..., L) sequence ascending: log(L) stages."""
    L = vals.shape[-1]
    if not _is_pow2(L):
        raise ValueError(f"bitonic_merge needs power-of-two length, got {L}")
    lane = lax.broadcasted_iota(jnp.int32, vals.shape, vals.ndim - 1)
    s = L // 2
    while s >= 1:
        take_smaller = (lane & s) == 0
        vals, idxs = _compare_exchange(vals, idxs, s, take_smaller)
        s //= 2
    return vals, idxs


def topk_update(buf_v, buf_i, cand_v, cand_i):
    """Streaming top-k update: merge k sorted-ascending candidates into a
    sorted-ascending (..., k) buffer. THE kernel-resident kNN queue step.

    buf asc + candidates asc:
      1. reverse candidates (desc);
      2. lane-wise lexicographic min into the buffer — after this the buffer
         holds exactly the k smallest of the union (each buffer lane's
         partner in the would-be 2k bitonic sequence), and is itself bitonic;
      3. one bitonic merge re-sorts ascending.
    Cost: log(k)+1 stages versus the FPGA queue's k-cycle drain.
    """
    if buf_v.shape != cand_v.shape:
        raise ValueError(f"buffer/candidates shape mismatch {buf_v.shape} vs {cand_v.shape}")
    rev_v = jnp.flip(cand_v, axis=-1)
    rev_i = jnp.flip(cand_i, axis=-1)
    take_rev = _pair_less(rev_v, rev_i, buf_v, buf_i)
    v = jnp.where(take_rev, rev_v, buf_v)
    i = jnp.where(take_rev, rev_i, buf_i)
    return bitonic_merge_ascending(v, i)


def sort_topk_tile(scores, idxs, k_eff: int):
    """Sort a (..., L) tile ascending and return its first k_eff columns."""
    v, i = bitonic_sort(scores, idxs)
    return v[..., :k_eff], i[..., :k_eff]


def tile_prunable(scores, queue_vals):
    """True iff NO element of a (bm, bn) score tile can enter the queues.

    This is the paper's kNN-queue insertion filter lifted to tile
    granularity: once the per-query queues are warm, a whole tile whose
    row-wise minimum cannot beat the queue's current worst entry carries
    zero insertable candidates, so the O(log^2 bn) bitonic sort and the
    merge can be skipped entirely.

    Pruning invariant (what keeps the pruned kernel bit-identical to the
    unpruned one): the comparison is STRICTLY greater-than. A candidate
    whose score merely *ties* the queue's worst value can still displace it
    through the lexicographic (value, index) tie-break, so tiles touching
    the threshold are never pruned. `queue_vals` is sorted ascending, hence
    its last column is the per-query worst ("kth-best") value.
    """
    worst = queue_vals[..., -1:]  # (bm, 1): per-query kth-best
    tile_min = jnp.min(scores, axis=-1, keepdims=True)  # (bm, 1)
    return jnp.all(tile_min > worst)
