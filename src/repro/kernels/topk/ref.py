"""Pure-jnp oracle for the streaming top-k kernel."""
import jax
import jax.numpy as jnp


def topk_ref(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """k smallest per row, sorted ascending, ties to smaller index.

    scores (M, N) -> (values (M, k) f32, indices (M, k) i32).
    """
    m, n = scores.shape
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (m, n))
    s, i = jax.lax.sort((scores.astype(jnp.float32), idx), dimension=-1, num_keys=2)
    if n >= k:
        return s[:, :k], i[:, :k]
    pad = k - n
    s = jnp.concatenate([s, jnp.full((m, pad), jnp.inf, jnp.float32)], axis=1)
    i = jnp.concatenate([i, jnp.full((m, pad), -1, jnp.int32)], axis=1)
    return s, i
