from repro.kernels.topk.ops import topk
