"""Jitted public wrapper for the streaming top-k kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.topk.kernel import topk_pallas


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("k", "block_m", "block_n", "interpret"))
def topk(
    scores: jax.Array,
    k: int,
    block_m: int = 128,
    block_n: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """k smallest per row of (M, N) scores: (values, indices) sorted asc.

    Pads N with +inf (never selected), M to the row block, k to the next
    power of two for the bitonic queue, then slices back. Out-of-range pad
    indices are mapped to -1.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = scores.shape
    k_eff = next_pow2(k)
    bn = max(block_n, k_eff)
    bm = block_m
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    s = jnp.pad(
        scores.astype(jnp.float32),
        ((0, mp - m), (0, np_ - n)),
        constant_values=jnp.inf,
    )
    v, i = topk_pallas(s, k_eff, bm, bn, interpret)
    v, i = v[:m, :k], i[:m, :k]
    return v, jnp.where(i < n, i, -1)
