"""Streaming top-k Pallas kernel — the paper's kNN queue as a VMEM resident.

Consumes a pre-computed (M, N) score matrix tile-by-tile along N (grid minor
axis) and maintains, per query row, a sorted top-k buffer in VMEM scratch —
the direct analogue of the FPGA's k-element systolic queue, with the
element-serial compare-swap chain replaced by lane-parallel bitonic stages
(see repro.kernels.bitonic).

Per n-step work on a (bm, bn) tile:
    bitonic sort of the tile rows            log^2(bn) stages
    queue merge (reverse + min + merge)      log(k)+1  stages
versus the queue's bn cycles — the VPU trades cycles for lanes.

Scratch persists across the sequential n grid steps (TPU grid is a sequential
loop with double-buffered input pipelining); results flush on the last step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.kernels.bitonic import bitonic_sort, topk_update


def _topk_kernel(
    s_ref, ov_ref, oi_ref, buf_v, buf_i, *, k_eff: int, n_steps: int, bn: int
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        buf_v[...] = jnp.full_like(buf_v, jnp.inf)
        buf_i[...] = jnp.full_like(buf_i, -1)

    tile = s_ref[...].astype(jnp.float32)  # (bm, bn)
    base = j * bn
    idx = base + lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    sv, si = bitonic_sort(tile, idx)
    buf_v[...], buf_i[...] = topk_update(
        buf_v[...], buf_i[...], sv[:, :k_eff], si[:, :k_eff]
    )

    @pl.when(j == n_steps - 1)
    def _flush():
        ov_ref[...] = buf_v[...]
        oi_ref[...] = buf_i[...]


@functools.partial(jax.jit, static_argnames=("k_eff", "block_m", "block_n", "interpret"))
def topk_pallas(
    scores: jax.Array,
    k_eff: int,
    block_m: int = 128,
    block_n: int = 1024,
    interpret: bool = False,
):
    """(M, N) -> ((M, k_eff), (M, k_eff)). Preconditions (see ops.py):
    k_eff power of two, k_eff <= block_n, M % block_m == 0, N % block_n == 0.
    """
    m, n = scores.shape
    bm, bn = block_m, block_n
    if n % bn or m % bm:
        raise ValueError(f"({m},{n}) not divisible by ({bm},{bn})")
    if k_eff > bn:
        raise ValueError(f"k_eff={k_eff} must be <= block_n={bn}")
    n_steps = n // bn
    grid = (m // bm, n_steps)
    kern = functools.partial(_topk_kernel, k_eff=k_eff, n_steps=n_steps, bn=bn)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, k_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k_eff), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k_eff), jnp.float32),
            jax.ShapeDtypeStruct((m, k_eff), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, k_eff), jnp.float32),
            pltpu.VMEM((bm, k_eff), jnp.int32),
        ],
        compiler_params=compat.tpu_compiler_params(
            ('parallel', 'arbitrary')
        ),
        interpret=interpret,
    )(scores)
